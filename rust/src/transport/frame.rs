//! Length-prefixed, versioned wire frames — the unit of everything
//! that crosses a transport link.
//!
//! A frame is a fixed 36-byte header followed by `payload_len` payload
//! bytes. The header is little-endian throughout and carries enough
//! context to reject a mismatched peer *before* any payload is
//! interpreted: magic + protocol version (wrong build), the run-config
//! fingerprint (wrong run), the codec widths (wrong comm plane), and
//! the sync index / fragment id of the payload (wrong schedule
//! position — and free observability on the wire).
//!
//! Layout (offsets in bytes):
//!
//! | off | size | field |
//! |-----|------|-------|
//! | 0   | 4    | magic `"DLCW"` |
//! | 4   | 2    | protocol version ([`PROTO_VERSION`]) |
//! | 6   | 1    | message kind ([`MsgKind`]) |
//! | 7   | 1    | up-wire codec width (bits; 0 = unspecified) |
//! | 8   | 1    | down-wire codec width (bits; 0 = unspecified) |
//! | 9   | 3    | reserved (must be zero) |
//! | 12  | 8    | run-config fingerprint (fnv1a64; 0 = unclaimed) |
//! | 20  | 8    | outer-sync index of the payload |
//! | 28  | 4    | fragment id (`u32::MAX` = none / full sync) |
//! | 32  | 4    | payload length |
//!
//! Decoding is hardened: truncated input, a bad magic, a version
//! mismatch, a nonzero reserved byte, an unknown kind, or an oversized
//! length all return a clean `Err` — never a panic, never a partial
//! read acted upon (`tests` pin each rejection).
//!
//! # The zero-copy wire path
//!
//! The hot path never assembles a frame by copying. A [`WireBuf`] is a
//! recycled byte buffer that reserves [`HEADER_LEN`] bytes of prefix;
//! encoders append payload directly after the prefix, and
//! [`WireBuf::frame`] stamps the header **in place**, yielding one
//! contiguous `write_all`-able frame with zero allocation and zero
//! payload memcpy. Borrowed payloads that don't live in a `WireBuf`
//! go out via [`write_frame`]'s vectored path (header on the stack,
//! payload straight from its owner). Received frames land in pooled
//! `WireBuf`s ([`read_frame_into`] + [`BufPool`]) and are carved into
//! shared [`WireSlice`] views, so multi-replica reports are consumed
//! without per-replica copies. The [`metrics`] counters audit the
//! discipline: steady-state socket syncs must show zero fresh wire
//! allocations and zero payload copies (pinned by
//! `tests/transport_loopback.rs`).

use std::io::{IoSlice, Read, Write};
use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

/// First bytes of every frame ("DiLoCo Wire").
pub const MAGIC: [u8; 4] = *b"DLCW";
/// Protocol version; bump on any incompatible frame or message change.
/// v2: streamed-broadcast `Bcast` frames + the `Pending` broadcast tag.
/// v3: streamed up-leg `ContribChunk` frames + the `Streamed` sync
/// payload tag.
pub const PROTO_VERSION: u16 = 3;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 36;
/// Per-frame framing overhead (the header *is* the length prefix —
/// `payload_len` lives inside it), used by `comm::wire` to report
/// framed bytes apples-to-apples with measured socket transfer.
pub const FRAME_OVERHEAD: u64 = HEADER_LEN as u64;
/// Upper bound on a single frame's payload (1 GiB) — a corrupted or
/// hostile length field must not turn into an allocation bomb.
pub const MAX_PAYLOAD: usize = 1 << 30;
/// Fragment-id sentinel for "no fragment" (full sync / non-sync frame).
pub const NO_FRAG: u32 = u32::MAX;

/// What a frame carries. Handshake kinds flow once per connection;
/// Run/Finish/Bcast flow coordinator→worker, Report/Error
/// worker→coordinator, Heartbeat worker→coordinator on its own cadence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgKind {
    /// Worker→coordinator: claimed replica ids (+ fingerprint/widths
    /// in the header, 0 = adopt the coordinator's).
    Hello,
    /// Coordinator→worker: accepted; payload = engine kind, initial
    /// liveness, and the run config JSON (the source of truth).
    Welcome,
    /// Coordinator→worker: refused; payload = human-readable reason.
    Reject,
    /// One segment command (`Cmd::Run`).
    Run,
    /// Final broadcast + shutdown (`Cmd::Finish`).
    Finish,
    /// A worker's segment report (losses + sync payloads).
    Report,
    /// A worker-side error, in place of a report (payload = message).
    Error,
    /// Liveness beacon; empty payload, consumed by the lane reactor.
    Heartbeat,
    /// A streamed broadcast payload, shipped at merge time ahead of
    /// the `Run` that references it (`Broadcast::Pending`). The header
    /// carries the sync index and fragment; the payload is the encoded
    /// broadcast bytes, flushed in encode-shard order.
    Bcast,
    /// One streamed shard of a replica's up-leg contribution, shipped
    /// worker→coordinator ahead of the `Report` that resolves it
    /// (`SyncPayload::Streamed`). The header carries the sync index
    /// and fragment; the payload is an 8-byte meta prefix
    /// (`u32` replica id, `u32` wire-byte offset — the shard's range
    /// is `offset..offset+len`) followed by the shard's encoded bytes,
    /// flushed in encode-shard (wire-offset) order per replica.
    ContribChunk,
}

impl MsgKind {
    pub fn code(self) -> u8 {
        match self {
            MsgKind::Hello => 1,
            MsgKind::Welcome => 2,
            MsgKind::Reject => 3,
            MsgKind::Run => 4,
            MsgKind::Finish => 5,
            MsgKind::Report => 6,
            MsgKind::Error => 7,
            MsgKind::Heartbeat => 8,
            MsgKind::Bcast => 9,
            MsgKind::ContribChunk => 10,
        }
    }

    pub fn parse(code: u8) -> Result<MsgKind> {
        Ok(match code {
            1 => MsgKind::Hello,
            2 => MsgKind::Welcome,
            3 => MsgKind::Reject,
            4 => MsgKind::Run,
            5 => MsgKind::Finish,
            6 => MsgKind::Report,
            7 => MsgKind::Error,
            8 => MsgKind::Heartbeat,
            9 => MsgKind::Bcast,
            10 => MsgKind::ContribChunk,
            other => bail!("frame: unknown message kind {other}"),
        })
    }
}

/// The decoded header (payload length is returned separately — it
/// describes the byte stream, not the message).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: MsgKind,
    /// Up-wire codec width in bits (0 = unspecified).
    pub up_bits: u8,
    /// Down-wire codec width in bits (0 = unspecified).
    pub down_bits: u8,
    /// Run-config fingerprint (0 = sender has not claimed one).
    pub fingerprint: u64,
    /// Outer-sync index the payload belongs to (0 when not applicable).
    pub sync_index: u64,
    /// Streaming fragment id (None = full sync / not applicable).
    pub frag: Option<u32>,
}

impl FrameHeader {
    /// A header with everything but the kind zeroed — handshake and
    /// heartbeat frames before a fingerprint exists.
    pub fn bare(kind: MsgKind) -> FrameHeader {
        FrameHeader {
            kind,
            up_bits: 0,
            down_bits: 0,
            fingerprint: 0,
            sync_index: 0,
            frag: None,
        }
    }
}

/// Transport-path allocation/copy audit counters. The zero-copy
/// discipline is enforced by tests that snapshot these around a
/// steady-state window and assert the deltas are zero; production code
/// only ever increments them (relaxed atomics — a few ns per event,
/// and steady state has no events).
pub mod metrics {
    use std::sync::atomic::{AtomicU64, Ordering};

    static WIRE_ALLOCS: AtomicU64 = AtomicU64::new(0);
    static PAYLOAD_COPIES: AtomicU64 = AtomicU64::new(0);

    /// A fresh wire buffer was allocated (a [`super::WireBuf`] built
    /// outside the recycle loop).
    pub fn count_wire_alloc() {
        WIRE_ALLOCS.fetch_add(1, Ordering::Relaxed);
    }

    /// Payload bytes were memcpy'd between buffers (staging copies the
    /// zero-copy path exists to eliminate).
    pub fn count_payload_copy() {
        PAYLOAD_COPIES.fetch_add(1, Ordering::Relaxed);
    }

    /// `(wire_allocs, payload_copies)` so far — diff two snapshots
    /// around a window to audit it.
    pub fn snapshot() -> (u64, u64) {
        (
            WIRE_ALLOCS.load(Ordering::Relaxed),
            PAYLOAD_COPIES.load(Ordering::Relaxed),
        )
    }
}

/// A recycled wire buffer with a [`HEADER_LEN`]-byte reserved prefix:
/// encoders write payload directly after the prefix, and
/// [`WireBuf::frame`] stamps the header in place — the whole frame
/// then ships as one `write_all`, no assembly copy, no allocation.
///
/// Invariant: the backing vec is always at least `HEADER_LEN` long;
/// everything past the prefix is payload.
pub struct WireBuf {
    buf: Vec<u8>,
}

impl Default for WireBuf {
    fn default() -> WireBuf {
        WireBuf::new()
    }
}

impl std::fmt::Debug for WireBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WireBuf({} payload bytes)", self.payload_len())
    }
}

impl PartialEq for WireBuf {
    fn eq(&self, other: &WireBuf) -> bool {
        self.payload() == other.payload()
    }
}

impl WireBuf {
    /// A fresh, empty-payload buffer. Counted by
    /// [`metrics::count_wire_alloc`] — steady-state hot paths must get
    /// theirs from a recycle pool instead.
    pub fn new() -> WireBuf {
        metrics::count_wire_alloc();
        WireBuf {
            buf: vec![0u8; HEADER_LEN],
        }
    }

    /// A buffer holding `payload` (copied — setup/test convenience,
    /// never the hot path; counted by both audit counters).
    pub fn from_payload(payload: &[u8]) -> WireBuf {
        metrics::count_payload_copy();
        let mut wb = WireBuf::new();
        wb.buf.extend_from_slice(payload);
        wb
    }

    /// Truncate the payload to zero, keeping capacity (the recycle
    /// entry point: every payload byte is rewritten on reuse).
    pub fn reset(&mut self) {
        self.buf.truncate(HEADER_LEN);
        // a buffer that was (ab)used as a raw vec could be shorter
        // than the prefix; restore the invariant
        if self.buf.len() < HEADER_LEN {
            self.buf.resize(HEADER_LEN, 0);
        }
    }

    pub fn payload_len(&self) -> usize {
        self.buf.len() - HEADER_LEN
    }

    pub fn payload(&self) -> &[u8] {
        &self.buf[HEADER_LEN..]
    }

    pub fn payload_mut(&mut self) -> &mut [u8] {
        &mut self.buf[HEADER_LEN..]
    }

    /// Resize the payload region to exactly `n` bytes (new bytes
    /// zeroed; encoders overwrite every byte anyway).
    pub fn resize_payload(&mut self, n: usize) {
        self.buf.resize(HEADER_LEN + n, 0);
    }

    /// Append bytes to the payload — a deliberate copy for small meta
    /// segments; payload-sized blobs must go through the vectored or
    /// in-place paths instead.
    pub fn extend_payload(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// The raw backing vec, positioned for append-only payload writes
    /// (the first [`HEADER_LEN`] bytes are the reserved prefix — do
    /// not truncate below it; [`WireBuf::reset`] repairs the invariant
    /// if a caller did).
    pub fn vec_for_append(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }

    /// Stamp `h` (with this buffer's payload length) into the reserved
    /// prefix and return the complete frame — header + payload, one
    /// contiguous slice, ready for a single `write_all`.
    pub fn frame(&mut self, h: &FrameHeader) -> Result<&[u8]> {
        let payload_len = self.payload_len();
        if payload_len > MAX_PAYLOAD {
            bail!(
                "frame: payload of {payload_len} bytes exceeds the {MAX_PAYLOAD} byte cap"
            );
        }
        write_header(&mut self.buf[..HEADER_LEN], h, payload_len);
        Ok(&self.buf)
    }
}

/// An immutable, shareable view of a sub-range of one [`WireBuf`]'s
/// payload. This is how received frames are consumed without copying:
/// one frame buffer, many per-replica payload views, all holding the
/// same `Arc`. When every view drops, [`reclaim_wires`] recovers the
/// buffer for the recycle pool.
#[derive(Clone)]
pub struct WireSlice {
    buf: Arc<WireBuf>,
    range: Range<usize>,
}

impl std::fmt::Debug for WireSlice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WireSlice({} bytes)", self.range.len())
    }
}

impl PartialEq for WireSlice {
    fn eq(&self, other: &WireSlice) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl WireSlice {
    /// The whole payload of `buf`.
    pub fn whole(buf: Arc<WireBuf>) -> WireSlice {
        let range = 0..buf.payload_len();
        WireSlice { buf, range }
    }

    /// A payload-relative sub-range of `buf` (panics on out-of-bounds —
    /// ranges come from the bounds-checked frame parser).
    pub fn part(buf: Arc<WireBuf>, range: Range<usize>) -> WireSlice {
        assert!(
            range.start <= range.end && range.end <= buf.payload_len(),
            "wire slice {range:?} outside a {} byte payload",
            buf.payload_len()
        );
        WireSlice { buf, range }
    }

    /// Copy `bytes` into a fresh buffer — setup/test convenience,
    /// never the hot path (audited by [`metrics`]).
    pub fn copied_from(bytes: &[u8]) -> WireSlice {
        WireSlice::whole(Arc::new(WireBuf::from_payload(bytes)))
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf.payload()[self.range.clone()]
    }

    pub fn len(&self) -> usize {
        self.range.len()
    }

    pub fn is_empty(&self) -> bool {
        self.range.is_empty()
    }

    /// The backing buffer (for `Arc::ptr_eq` dedup during reclaim).
    pub fn buf(&self) -> &Arc<WireBuf> {
        &self.buf
    }
}

/// Recover the unique backing buffers from a batch of spent payload
/// views: dedupe by `Arc` identity (many views of one received frame
/// count once), then unwrap the `Arc`s whose every view has dropped.
/// Buffers still shared elsewhere are left to their holders.
pub fn reclaim_wires(slices: Vec<WireSlice>) -> Vec<WireBuf> {
    let mut arcs: Vec<Arc<WireBuf>> = Vec::with_capacity(slices.len());
    for s in slices {
        if !arcs.iter().any(|a| Arc::ptr_eq(a, &s.buf)) {
            arcs.push(s.buf);
        }
    }
    arcs.into_iter()
        .filter_map(|a| Arc::try_unwrap(a).ok())
        .collect()
}

/// A bounded recycle pool of [`WireBuf`]s. `take` prefers a pooled
/// buffer (reset, capacity retained) and only allocates — audited —
/// when the pool is dry; `put` drops beyond the cap so a burst can't
/// pin unbounded memory.
pub struct BufPool {
    free: Vec<WireBuf>,
    cap: usize,
}

impl BufPool {
    pub fn with_cap(cap: usize) -> BufPool {
        BufPool {
            free: Vec::new(),
            cap,
        }
    }

    pub fn take(&mut self) -> WireBuf {
        match self.free.pop() {
            Some(mut b) => {
                b.reset();
                b
            }
            None => WireBuf::new(),
        }
    }

    pub fn put(&mut self, b: WireBuf) {
        if self.free.len() < self.cap {
            self.free.push(b);
        }
    }

    pub fn len(&self) -> usize {
        self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.free.is_empty()
    }
}

/// Serialize `h` into a `dst` of at least [`HEADER_LEN`] bytes — the
/// one place the byte layout lives (in-place stamping, stack headers,
/// and `encode_frame` all route here, so the golden-bytes test pins
/// them all at once).
fn write_header(dst: &mut [u8], h: &FrameHeader, payload_len: usize) {
    dst[0..4].copy_from_slice(&MAGIC);
    dst[4..6].copy_from_slice(&PROTO_VERSION.to_le_bytes());
    dst[6] = h.kind.code();
    dst[7] = h.up_bits;
    dst[8] = h.down_bits;
    dst[9..12].copy_from_slice(&[0u8; 3]);
    dst[12..20].copy_from_slice(&h.fingerprint.to_le_bytes());
    dst[20..28].copy_from_slice(&h.sync_index.to_le_bytes());
    dst[28..32].copy_from_slice(&h.frag.unwrap_or(NO_FRAG).to_le_bytes());
    dst[32..36].copy_from_slice(&(payload_len as u32).to_le_bytes());
}

/// The 36 header bytes for a frame of `payload_len`, on the stack —
/// the vectored write path's first `IoSlice`.
pub fn header_bytes(h: &FrameHeader, payload_len: usize) -> Result<[u8; HEADER_LEN]> {
    if payload_len > MAX_PAYLOAD {
        bail!(
            "frame: payload of {payload_len} bytes exceeds the {MAX_PAYLOAD} byte cap"
        );
    }
    let mut hdr = [0u8; HEADER_LEN];
    write_header(&mut hdr, h, payload_len);
    Ok(hdr)
}

/// Append one encoded frame (header + payload) to `out`.
pub fn encode_frame(h: &FrameHeader, payload: &[u8], out: &mut Vec<u8>) -> Result<()> {
    let hdr = header_bytes(h, payload.len())?;
    out.reserve(HEADER_LEN + payload.len());
    out.extend_from_slice(&hdr);
    out.extend_from_slice(payload);
    Ok(())
}

fn le_u16(b: &[u8]) -> u16 {
    u16::from_le_bytes([b[0], b[1]])
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Parse and validate one header; returns the payload length it
/// announces. Rejects (clean `Err`) on truncation, bad magic, version
/// mismatch, nonzero reserved bytes, unknown kind, or oversized length.
pub fn parse_header(buf: &[u8]) -> Result<(FrameHeader, usize)> {
    if buf.len() < HEADER_LEN {
        bail!(
            "frame: truncated header ({} of {HEADER_LEN} bytes)",
            buf.len()
        );
    }
    if buf[0..4] != MAGIC {
        bail!("frame: bad magic {:02x?} (want {MAGIC:02x?})", &buf[0..4]);
    }
    let version = le_u16(&buf[4..6]);
    if version != PROTO_VERSION {
        bail!("frame: protocol version {version} (this build speaks {PROTO_VERSION})");
    }
    let kind = MsgKind::parse(buf[6])?;
    if buf[9..12] != [0u8; 3] {
        bail!("frame: nonzero reserved bytes {:02x?}", &buf[9..12]);
    }
    let payload_len = le_u32(&buf[32..36]) as usize;
    if payload_len > MAX_PAYLOAD {
        bail!("frame: payload length {payload_len} exceeds the {MAX_PAYLOAD} byte cap");
    }
    let frag = le_u32(&buf[28..32]);
    Ok((
        FrameHeader {
            kind,
            up_bits: buf[7],
            down_bits: buf[8],
            fingerprint: le_u64(&buf[12..20]),
            sync_index: le_u64(&buf[20..28]),
            frag: (frag != NO_FRAG).then_some(frag),
        },
        payload_len,
    ))
}

/// Decode one full frame from a buffer; returns the header, the
/// payload slice, and the total bytes consumed.
pub fn decode_frame(buf: &[u8]) -> Result<(FrameHeader, &[u8], usize)> {
    let (h, payload_len) = parse_header(buf)?;
    let total = HEADER_LEN + payload_len;
    if buf.len() < total {
        bail!(
            "frame: truncated payload ({} of {payload_len} bytes present)",
            buf.len() - HEADER_LEN
        );
    }
    Ok((h, &buf[HEADER_LEN..total], total))
}

/// Read one frame off a stream (blocking; honors the stream's read
/// timeout). A clean EOF before the first header byte reports as an
/// error too — callers decide whether that ends a session gracefully.
pub fn read_frame(r: &mut impl Read) -> Result<(FrameHeader, Vec<u8>)> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr).context("frame: reading header")?;
    let (h, payload_len) = parse_header(&hdr)?;
    let mut payload = vec![0u8; payload_len];
    r.read_exact(&mut payload)
        .with_context(|| format!("frame: reading {payload_len} byte payload"))?;
    Ok((h, payload))
}

/// Read one frame off a stream into a recycled buffer: header on the
/// stack, payload straight into `buf` (resized, capacity retained
/// across frames) — the receive leg's zero-alloc twin of
/// [`WireBuf::frame`].
pub fn read_frame_into(r: &mut impl Read, buf: &mut WireBuf) -> Result<FrameHeader> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr).context("frame: reading header")?;
    let (h, payload_len) = parse_header(&hdr)?;
    buf.reset();
    buf.resize_payload(payload_len);
    r.read_exact(buf.payload_mut())
        .with_context(|| format!("frame: reading {payload_len} byte payload"))?;
    Ok(h)
}

/// Write every byte of `parts`, preferring one vectored syscall;
/// resumes correctly across short writes. The degenerate single-part
/// call is just `write_all`.
pub fn write_all_vectored(w: &mut impl Write, parts: &[&[u8]]) -> Result<()> {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut written = 0usize;
    while written < total {
        // rebuild the slice list past what's already gone (short
        // writes are rare; the steady state is one pass)
        let mut skip = written;
        let mut bufs: Vec<IoSlice> = Vec::with_capacity(parts.len());
        for p in parts {
            if skip >= p.len() {
                skip -= p.len();
                continue;
            }
            bufs.push(IoSlice::new(&p[skip..]));
            skip = 0;
        }
        let n = w.write_vectored(&bufs).context("frame: writing")?;
        if n == 0 {
            bail!("frame: writer accepted zero bytes");
        }
        written += n;
    }
    Ok(())
}

/// Write one frame to a stream: header on the stack, payload borrowed,
/// shipped as one vectored write — no assembly buffer, no copy. (The
/// two `IoSlice`s reach the kernel as one atomic writev on the
/// platforms we run, and every concurrent writer in this crate is
/// serialized by a lock anyway.)
pub fn write_frame(w: &mut impl Write, h: &FrameHeader, payload: &[u8]) -> Result<()> {
    let hdr = header_bytes(h, payload.len())?;
    write_all_vectored(w, &[&hdr, payload])
}

/// The retired copying writer — assembles header + payload into a
/// fresh `Vec` per frame. Kept only as the bench baseline the
/// zero-copy path is measured against (`bench_hot_path`: "transport
/// frame write" vs the "copy baseline" case).
#[doc(hidden)]
pub fn write_frame_copying(w: &mut impl Write, h: &FrameHeader, payload: &[u8]) -> Result<()> {
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    encode_frame(h, payload, &mut buf)?;
    w.write_all(&buf).context("frame: writing")?;
    Ok(())
}

/// FNV-1a (64-bit) — the run-config fingerprint hash. Chosen for
/// being trivially reimplementable by any peer, not for strength: the
/// handshake guards against configuration drift, not adversaries.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_header() -> FrameHeader {
        FrameHeader {
            kind: MsgKind::Run,
            up_bits: 4,
            down_bits: 8,
            fingerprint: 0x1122_3344_5566_7788,
            sync_index: 7,
            frag: Some(2),
        }
    }

    #[test]
    fn golden_header_bytes() {
        let mut buf = Vec::new();
        encode_frame(&sample_header(), b"xyz", &mut buf).unwrap();
        // the exact wire layout, byte for byte — if this changes,
        // PROTO_VERSION must bump (v3 = streamed up-leg contributions)
        #[rustfmt::skip]
        let want: [u8; HEADER_LEN] = [
            b'D', b'L', b'C', b'W',             // magic
            3, 0,                               // version 3 LE
            4,                                  // kind = Run
            4, 8,                               // up / down bits
            0, 0, 0,                            // reserved
            0x88, 0x77, 0x66, 0x55, 0x44, 0x33, 0x22, 0x11, // fingerprint LE
            7, 0, 0, 0, 0, 0, 0, 0,             // sync index LE
            2, 0, 0, 0,                         // fragment id LE
            3, 0, 0, 0,                         // payload length LE
        ];
        assert_eq!(&buf[..HEADER_LEN], &want);
        assert_eq!(&buf[HEADER_LEN..], b"xyz");
        assert_eq!(buf.len() as u64, FRAME_OVERHEAD + 3);
    }

    #[test]
    fn in_place_framing_matches_the_copying_encoder() {
        // the zero-copy path (payload written after the reserved
        // prefix, header stamped in place) must produce byte-identical
        // frames to encode_frame
        let mut oracle = Vec::new();
        encode_frame(&sample_header(), b"hello wire", &mut oracle).unwrap();

        let mut wb = WireBuf::new();
        wb.extend_payload(b"hello wire");
        let framed = wb.frame(&sample_header()).unwrap();
        assert_eq!(framed, &oracle[..]);

        // recycled reuse rewrites every byte — dirty state never leaks
        wb.reset();
        assert_eq!(wb.payload_len(), 0);
        wb.extend_payload(b"xyz");
        let mut oracle2 = Vec::new();
        encode_frame(&sample_header(), b"xyz", &mut oracle2).unwrap();
        assert_eq!(wb.frame(&sample_header()).unwrap(), &oracle2[..]);

        // and the vectored writer produces the same stream
        let mut sink = Vec::new();
        write_frame(&mut sink, &sample_header(), b"hello wire").unwrap();
        assert_eq!(sink, oracle);
        let mut sink2 = Vec::new();
        write_frame_copying(&mut sink2, &sample_header(), b"hello wire").unwrap();
        assert_eq!(sink2, oracle);
    }

    #[test]
    fn read_frame_into_reuses_the_buffer() {
        let mut stream = Vec::new();
        encode_frame(&sample_header(), &[7u8; 20], &mut stream).unwrap();
        encode_frame(&FrameHeader::bare(MsgKind::Heartbeat), &[], &mut stream).unwrap();
        let mut rd = &stream[..];
        let mut buf = WireBuf::from_payload(&[0xAA; 64]); // dirty recycled buffer
        let h = read_frame_into(&mut rd, &mut buf).unwrap();
        assert_eq!(h, sample_header());
        assert_eq!(buf.payload(), &[7u8; 20]);
        let h2 = read_frame_into(&mut rd, &mut buf).unwrap();
        assert_eq!(h2.kind, MsgKind::Heartbeat);
        assert_eq!(buf.payload_len(), 0);
    }

    #[test]
    fn wire_slices_share_one_buffer_and_reclaim_once() {
        let buf = Arc::new(WireBuf::from_payload(&[1, 2, 3, 4, 5, 6]));
        let a = WireSlice::part(Arc::clone(&buf), 0..2);
        let b = WireSlice::part(Arc::clone(&buf), 2..6);
        let whole = WireSlice::whole(Arc::clone(&buf));
        assert_eq!(a.as_slice(), &[1, 2]);
        assert_eq!(b.as_slice(), &[3, 4, 5, 6]);
        assert_eq!(whole.len(), 6);
        drop(buf);
        // while `whole` is alive the backing buffer can't be reclaimed
        let held = reclaim_wires(vec![a.clone(), b.clone()]);
        assert!(held.is_empty(), "shared buffer must not be unwrapped");
        // once every view is in the batch, exactly one buffer returns
        let got = reclaim_wires(vec![a, b, whole]);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].payload(), &[1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn buf_pool_recycles_and_caps() {
        let mut pool = BufPool::with_cap(2);
        let (allocs0, _) = metrics::snapshot();
        let mut a = pool.take(); // dry pool: one audited alloc
        a.extend_payload(b"dirty");
        pool.put(a);
        let b = pool.take(); // recycled: reset, no alloc
        assert_eq!(b.payload_len(), 0);
        pool.put(b);
        let (allocs1, _) = metrics::snapshot();
        assert_eq!(allocs1 - allocs0, 1, "one alloc for the dry take only");
        pool.put(WireBuf::new());
        pool.put(WireBuf::new());
        pool.put(WireBuf::new());
        assert_eq!(pool.len(), 2, "pool drops beyond its cap");
    }

    #[test]
    fn vectored_writes_survive_short_writers() {
        // a writer that accepts one byte at a time still gets the
        // whole frame, in order
        struct Trickle(Vec<u8>);
        impl Write for Trickle {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                if buf.is_empty() {
                    return Ok(0);
                }
                self.0.push(buf[0]);
                Ok(1)
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut t = Trickle(Vec::new());
        write_all_vectored(&mut t, &[b"abc", b"", b"defg", b"h"]).unwrap();
        assert_eq!(t.0, b"abcdefgh");
        let mut t = Trickle(Vec::new());
        write_frame(&mut t, &sample_header(), b"xyz").unwrap();
        let mut oracle = Vec::new();
        encode_frame(&sample_header(), b"xyz", &mut oracle).unwrap();
        assert_eq!(t.0, oracle);
    }

    #[test]
    fn roundtrips_and_reports_consumed_length() {
        let mut buf = Vec::new();
        encode_frame(&sample_header(), &[9u8; 10], &mut buf).unwrap();
        // trailing bytes beyond the frame are left untouched
        buf.extend_from_slice(&[0xAA; 5]);
        let (h, payload, used) = decode_frame(&buf).unwrap();
        assert_eq!(h, sample_header());
        assert_eq!(payload, &[9u8; 10]);
        assert_eq!(used, HEADER_LEN + 10);

        // no-fragment sentinel round-trips as None
        let mut buf = Vec::new();
        encode_frame(&FrameHeader::bare(MsgKind::Heartbeat), &[], &mut buf).unwrap();
        let (h, payload, _) = decode_frame(&buf).unwrap();
        assert_eq!(h.frag, None);
        assert!(payload.is_empty());
    }

    #[test]
    fn rejects_truncated_frames_cleanly() {
        let mut buf = Vec::new();
        encode_frame(&sample_header(), b"payload", &mut buf).unwrap();
        // every possible truncation point: clean Err, never a panic
        for cut in 0..buf.len() {
            let err = decode_frame(&buf[..cut]).expect_err("truncated frame must be rejected");
            let msg = format!("{err:#}");
            assert!(msg.contains("truncated"), "cut={cut}: {msg}");
        }
        assert!(decode_frame(&buf).is_ok(), "the full frame still decodes");
    }

    #[test]
    fn rejects_oversized_length() {
        let mut buf = Vec::new();
        encode_frame(&sample_header(), b"x", &mut buf).unwrap();
        // corrupt the length field to just over the cap
        buf[32..36].copy_from_slice(&((MAX_PAYLOAD as u32) + 1).to_le_bytes());
        let err = decode_frame(&buf).expect_err("oversized length must be rejected");
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
        // and the encoder refuses to produce one in the first place
        let huge = vec![0u8; MAX_PAYLOAD + 1];
        assert!(encode_frame(&sample_header(), &huge, &mut Vec::new()).is_err());
    }

    #[test]
    fn rejects_version_mismatch_and_bad_magic() {
        let mut buf = Vec::new();
        encode_frame(&sample_header(), b"", &mut buf).unwrap();
        let mut wrong_version = buf.clone();
        wrong_version[4..6].copy_from_slice(&(PROTO_VERSION + 1).to_le_bytes());
        let err = decode_frame(&wrong_version).expect_err("version mismatch");
        assert!(format!("{err:#}").contains("protocol version"), "{err:#}");

        let mut wrong_magic = buf.clone();
        wrong_magic[0] = b'X';
        assert!(decode_frame(&wrong_magic).is_err());

        let mut wrong_kind = buf.clone();
        wrong_kind[6] = 99;
        assert!(decode_frame(&wrong_kind).is_err());

        let mut dirty_reserved = buf;
        dirty_reserved[10] = 1;
        assert!(decode_frame(&dirty_reserved).is_err());
    }

    #[test]
    fn every_kind_roundtrips() {
        for k in [
            MsgKind::Hello,
            MsgKind::Welcome,
            MsgKind::Reject,
            MsgKind::Run,
            MsgKind::Finish,
            MsgKind::Report,
            MsgKind::Error,
            MsgKind::Heartbeat,
            MsgKind::Bcast,
            MsgKind::ContribChunk,
        ] {
            assert_eq!(MsgKind::parse(k.code()).unwrap(), k);
        }
        assert!(MsgKind::parse(0).is_err());
    }

    #[test]
    fn fingerprint_is_stable() {
        // pinned: the handshake compares these across builds and
        // machines, so the hash can never silently change
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"diloco"), fnv1a64(b"diloco"));
        assert_ne!(fnv1a64(b"diloco"), fnv1a64(b"dilocO"));
    }
}
