//! Length-prefixed, versioned wire frames — the unit of everything
//! that crosses a transport link.
//!
//! A frame is a fixed 36-byte header followed by `payload_len` payload
//! bytes. The header is little-endian throughout and carries enough
//! context to reject a mismatched peer *before* any payload is
//! interpreted: magic + protocol version (wrong build), the run-config
//! fingerprint (wrong run), the codec widths (wrong comm plane), and
//! the sync index / fragment id of the payload (wrong schedule
//! position — and free observability on the wire).
//!
//! Layout (offsets in bytes):
//!
//! | off | size | field |
//! |-----|------|-------|
//! | 0   | 4    | magic `"DLCW"` |
//! | 4   | 2    | protocol version ([`PROTO_VERSION`]) |
//! | 6   | 1    | message kind ([`MsgKind`]) |
//! | 7   | 1    | up-wire codec width (bits; 0 = unspecified) |
//! | 8   | 1    | down-wire codec width (bits; 0 = unspecified) |
//! | 9   | 3    | reserved (must be zero) |
//! | 12  | 8    | run-config fingerprint (fnv1a64; 0 = unclaimed) |
//! | 20  | 8    | outer-sync index of the payload |
//! | 28  | 4    | fragment id (`u32::MAX` = none / full sync) |
//! | 32  | 4    | payload length |
//!
//! Decoding is hardened: truncated input, a bad magic, a version
//! mismatch, a nonzero reserved byte, an unknown kind, or an oversized
//! length all return a clean `Err` — never a panic, never a partial
//! read acted upon (`tests` pin each rejection).

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// First bytes of every frame ("DiLoCo Wire").
pub const MAGIC: [u8; 4] = *b"DLCW";
/// Protocol version; bump on any incompatible frame or message change.
pub const PROTO_VERSION: u16 = 1;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 36;
/// Per-frame framing overhead (the header *is* the length prefix —
/// `payload_len` lives inside it), used by `comm::wire` to report
/// framed bytes apples-to-apples with measured socket transfer.
pub const FRAME_OVERHEAD: u64 = HEADER_LEN as u64;
/// Upper bound on a single frame's payload (1 GiB) — a corrupted or
/// hostile length field must not turn into an allocation bomb.
pub const MAX_PAYLOAD: usize = 1 << 30;
/// Fragment-id sentinel for "no fragment" (full sync / non-sync frame).
pub const NO_FRAG: u32 = u32::MAX;

/// What a frame carries. Handshake kinds flow once per connection;
/// Run/Finish flow coordinator→worker, Report/Error worker→coordinator,
/// Heartbeat worker→coordinator on its own cadence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgKind {
    /// Worker→coordinator: claimed replica ids (+ fingerprint/widths
    /// in the header, 0 = adopt the coordinator's).
    Hello,
    /// Coordinator→worker: accepted; payload = engine kind, initial
    /// liveness, and the run config JSON (the source of truth).
    Welcome,
    /// Coordinator→worker: refused; payload = human-readable reason.
    Reject,
    /// One segment command (`Cmd::Run`).
    Run,
    /// Final broadcast + shutdown (`Cmd::Finish`).
    Finish,
    /// A worker's segment report (losses + sync payloads).
    Report,
    /// A worker-side error, in place of a report (payload = message).
    Error,
    /// Liveness beacon; empty payload, skipped by receivers.
    Heartbeat,
}

impl MsgKind {
    pub fn code(self) -> u8 {
        match self {
            MsgKind::Hello => 1,
            MsgKind::Welcome => 2,
            MsgKind::Reject => 3,
            MsgKind::Run => 4,
            MsgKind::Finish => 5,
            MsgKind::Report => 6,
            MsgKind::Error => 7,
            MsgKind::Heartbeat => 8,
        }
    }

    pub fn parse(code: u8) -> Result<MsgKind> {
        Ok(match code {
            1 => MsgKind::Hello,
            2 => MsgKind::Welcome,
            3 => MsgKind::Reject,
            4 => MsgKind::Run,
            5 => MsgKind::Finish,
            6 => MsgKind::Report,
            7 => MsgKind::Error,
            8 => MsgKind::Heartbeat,
            other => bail!("frame: unknown message kind {other}"),
        })
    }
}

/// The decoded header (payload length is returned separately — it
/// describes the byte stream, not the message).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: MsgKind,
    /// Up-wire codec width in bits (0 = unspecified).
    pub up_bits: u8,
    /// Down-wire codec width in bits (0 = unspecified).
    pub down_bits: u8,
    /// Run-config fingerprint (0 = sender has not claimed one).
    pub fingerprint: u64,
    /// Outer-sync index the payload belongs to (0 when not applicable).
    pub sync_index: u64,
    /// Streaming fragment id (None = full sync / not applicable).
    pub frag: Option<u32>,
}

impl FrameHeader {
    /// A header with everything but the kind zeroed — handshake and
    /// heartbeat frames before a fingerprint exists.
    pub fn bare(kind: MsgKind) -> FrameHeader {
        FrameHeader {
            kind,
            up_bits: 0,
            down_bits: 0,
            fingerprint: 0,
            sync_index: 0,
            frag: None,
        }
    }
}

/// Append one encoded frame (header + payload) to `out`.
pub fn encode_frame(h: &FrameHeader, payload: &[u8], out: &mut Vec<u8>) -> Result<()> {
    if payload.len() > MAX_PAYLOAD {
        bail!(
            "frame: payload of {} bytes exceeds the {} byte cap",
            payload.len(),
            MAX_PAYLOAD
        );
    }
    out.reserve(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    out.push(h.kind.code());
    out.push(h.up_bits);
    out.push(h.down_bits);
    out.extend_from_slice(&[0u8; 3]);
    out.extend_from_slice(&h.fingerprint.to_le_bytes());
    out.extend_from_slice(&h.sync_index.to_le_bytes());
    out.extend_from_slice(&h.frag.unwrap_or(NO_FRAG).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    Ok(())
}

fn le_u16(b: &[u8]) -> u16 {
    u16::from_le_bytes([b[0], b[1]])
}

fn le_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Parse and validate one header; returns the payload length it
/// announces. Rejects (clean `Err`) on truncation, bad magic, version
/// mismatch, nonzero reserved bytes, unknown kind, or oversized length.
pub fn parse_header(buf: &[u8]) -> Result<(FrameHeader, usize)> {
    if buf.len() < HEADER_LEN {
        bail!(
            "frame: truncated header ({} of {HEADER_LEN} bytes)",
            buf.len()
        );
    }
    if buf[0..4] != MAGIC {
        bail!("frame: bad magic {:02x?} (want {MAGIC:02x?})", &buf[0..4]);
    }
    let version = le_u16(&buf[4..6]);
    if version != PROTO_VERSION {
        bail!("frame: protocol version {version} (this build speaks {PROTO_VERSION})");
    }
    let kind = MsgKind::parse(buf[6])?;
    if buf[9..12] != [0u8; 3] {
        bail!("frame: nonzero reserved bytes {:02x?}", &buf[9..12]);
    }
    let payload_len = le_u32(&buf[32..36]) as usize;
    if payload_len > MAX_PAYLOAD {
        bail!("frame: payload length {payload_len} exceeds the {MAX_PAYLOAD} byte cap");
    }
    let frag = le_u32(&buf[28..32]);
    Ok((
        FrameHeader {
            kind,
            up_bits: buf[7],
            down_bits: buf[8],
            fingerprint: le_u64(&buf[12..20]),
            sync_index: le_u64(&buf[20..28]),
            frag: (frag != NO_FRAG).then_some(frag),
        },
        payload_len,
    ))
}

/// Decode one full frame from a buffer; returns the header, the
/// payload slice, and the total bytes consumed.
pub fn decode_frame(buf: &[u8]) -> Result<(FrameHeader, &[u8], usize)> {
    let (h, payload_len) = parse_header(buf)?;
    let total = HEADER_LEN + payload_len;
    if buf.len() < total {
        bail!(
            "frame: truncated payload ({} of {payload_len} bytes present)",
            buf.len() - HEADER_LEN
        );
    }
    Ok((h, &buf[HEADER_LEN..total], total))
}

/// Read one frame off a stream (blocking; honors the stream's read
/// timeout). A clean EOF before the first header byte reports as an
/// error too — callers decide whether that ends a session gracefully.
pub fn read_frame(r: &mut impl Read) -> Result<(FrameHeader, Vec<u8>)> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr).context("frame: reading header")?;
    let (h, payload_len) = parse_header(&hdr)?;
    let mut payload = vec![0u8; payload_len];
    r.read_exact(&mut payload)
        .with_context(|| format!("frame: reading {payload_len} byte payload"))?;
    Ok((h, payload))
}

/// Write one frame to a stream as a single `write_all` (one contiguous
/// buffer, so concurrent writers serialized by a lock never interleave
/// partial frames).
pub fn write_frame(w: &mut impl Write, h: &FrameHeader, payload: &[u8]) -> Result<()> {
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    encode_frame(h, payload, &mut buf)?;
    w.write_all(&buf).context("frame: writing")?;
    Ok(())
}

/// FNV-1a (64-bit) — the run-config fingerprint hash. Chosen for
/// being trivially reimplementable by any peer, not for strength: the
/// handshake guards against configuration drift, not adversaries.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_header() -> FrameHeader {
        FrameHeader {
            kind: MsgKind::Run,
            up_bits: 4,
            down_bits: 8,
            fingerprint: 0x1122_3344_5566_7788,
            sync_index: 7,
            frag: Some(2),
        }
    }

    #[test]
    fn golden_header_bytes() {
        let mut buf = Vec::new();
        encode_frame(&sample_header(), b"xyz", &mut buf).unwrap();
        // the exact wire layout, byte for byte — if this changes,
        // PROTO_VERSION must bump
        #[rustfmt::skip]
        let want: [u8; HEADER_LEN] = [
            b'D', b'L', b'C', b'W',             // magic
            1, 0,                               // version 1 LE
            4,                                  // kind = Run
            4, 8,                               // up / down bits
            0, 0, 0,                            // reserved
            0x88, 0x77, 0x66, 0x55, 0x44, 0x33, 0x22, 0x11, // fingerprint LE
            7, 0, 0, 0, 0, 0, 0, 0,             // sync index LE
            2, 0, 0, 0,                         // fragment id LE
            3, 0, 0, 0,                         // payload length LE
        ];
        assert_eq!(&buf[..HEADER_LEN], &want);
        assert_eq!(&buf[HEADER_LEN..], b"xyz");
        assert_eq!(buf.len() as u64, FRAME_OVERHEAD + 3);
    }

    #[test]
    fn roundtrips_and_reports_consumed_length() {
        let mut buf = Vec::new();
        encode_frame(&sample_header(), &[9u8; 10], &mut buf).unwrap();
        // trailing bytes beyond the frame are left untouched
        buf.extend_from_slice(&[0xAA; 5]);
        let (h, payload, used) = decode_frame(&buf).unwrap();
        assert_eq!(h, sample_header());
        assert_eq!(payload, &[9u8; 10]);
        assert_eq!(used, HEADER_LEN + 10);

        // no-fragment sentinel round-trips as None
        let mut buf = Vec::new();
        encode_frame(&FrameHeader::bare(MsgKind::Heartbeat), &[], &mut buf).unwrap();
        let (h, payload, _) = decode_frame(&buf).unwrap();
        assert_eq!(h.frag, None);
        assert!(payload.is_empty());
    }

    #[test]
    fn rejects_truncated_frames_cleanly() {
        let mut buf = Vec::new();
        encode_frame(&sample_header(), b"payload", &mut buf).unwrap();
        // every possible truncation point: clean Err, never a panic
        for cut in 0..buf.len() {
            let err = decode_frame(&buf[..cut]).expect_err("truncated frame must be rejected");
            let msg = format!("{err:#}");
            assert!(msg.contains("truncated"), "cut={cut}: {msg}");
        }
        assert!(decode_frame(&buf).is_ok(), "the full frame still decodes");
    }

    #[test]
    fn rejects_oversized_length() {
        let mut buf = Vec::new();
        encode_frame(&sample_header(), b"x", &mut buf).unwrap();
        // corrupt the length field to just over the cap
        buf[32..36].copy_from_slice(&((MAX_PAYLOAD as u32) + 1).to_le_bytes());
        let err = decode_frame(&buf).expect_err("oversized length must be rejected");
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
        // and the encoder refuses to produce one in the first place
        let huge = vec![0u8; MAX_PAYLOAD + 1];
        assert!(encode_frame(&sample_header(), &huge, &mut Vec::new()).is_err());
    }

    #[test]
    fn rejects_version_mismatch_and_bad_magic() {
        let mut buf = Vec::new();
        encode_frame(&sample_header(), b"", &mut buf).unwrap();
        let mut wrong_version = buf.clone();
        wrong_version[4..6].copy_from_slice(&(PROTO_VERSION + 1).to_le_bytes());
        let err = decode_frame(&wrong_version).expect_err("version mismatch");
        assert!(format!("{err:#}").contains("protocol version"), "{err:#}");

        let mut wrong_magic = buf.clone();
        wrong_magic[0] = b'X';
        assert!(decode_frame(&wrong_magic).is_err());

        let mut wrong_kind = buf.clone();
        wrong_kind[6] = 99;
        assert!(decode_frame(&wrong_kind).is_err());

        let mut dirty_reserved = buf;
        dirty_reserved[10] = 1;
        assert!(decode_frame(&dirty_reserved).is_err());
    }

    #[test]
    fn every_kind_roundtrips() {
        for k in [
            MsgKind::Hello,
            MsgKind::Welcome,
            MsgKind::Reject,
            MsgKind::Run,
            MsgKind::Finish,
            MsgKind::Report,
            MsgKind::Error,
            MsgKind::Heartbeat,
        ] {
            assert_eq!(MsgKind::parse(k.code()).unwrap(), k);
        }
        assert!(MsgKind::parse(0).is_err());
    }

    #[test]
    fn fingerprint_is_stable() {
        // pinned: the handshake compares these across builds and
        // machines, so the hash can never silently change
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"diloco"), fnv1a64(b"diloco"));
        assert_ne!(fnv1a64(b"diloco"), fnv1a64(b"dilocO"));
    }
}
