//! Typed configuration layer over `configs/models.json` (the single
//! source of truth shared with the python AOT pipeline).

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::json::Json;

/// Paper-ladder entry (Table 3) — used only by the analytic simulators.
#[derive(Debug, Clone)]
pub struct PaperModel {
    pub name: String,
    pub layers: usize,
    pub heads: usize,
    pub qkv_dim: usize,
    pub hidden_dim: usize,
    pub params: f64,
    pub token_budget: f64,
}

/// Optimizer policy (paper section 3: AdamW inner, Nesterov outer,
/// warmup+cosine schedule, weight decay 1/T).
#[derive(Debug, Clone)]
pub struct OptimizerPolicy {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub grad_clip: f64,
    pub outer_momentum: f64,
    pub warmup_frac: f64,
    pub warmup_cap: usize,
    pub final_lr_frac: f64,
}

#[derive(Debug, Clone)]
pub struct RepoConfig {
    pub root: PathBuf,
    pub vocab: usize,
    pub seq_len: usize,
    pub token_multiplier: f64,
    pub mini_models: Vec<String>,
    pub paper_ladder: Vec<PaperModel>,
    pub optimizer: OptimizerPolicy,
    pub eval_batch: usize,
}

/// Locate the repo root by walking up from cwd looking for configs/.
pub fn find_root() -> Result<PathBuf> {
    let mut dir = std::env::current_dir()?;
    loop {
        if dir.join("configs/models.json").is_file() {
            return Ok(dir);
        }
        if !dir.pop() {
            anyhow::bail!("configs/models.json not found above cwd");
        }
    }
}

impl RepoConfig {
    pub fn load_default() -> Result<RepoConfig> {
        Self::load(&find_root()?)
    }

    pub fn load(root: &Path) -> Result<RepoConfig> {
        let j = Json::parse_file(&root.join("configs/models.json"))?;
        let tok = j.req("tokenizer")?;
        let opt = j.req("optimizer")?;
        let inner = opt.req("inner")?;
        let outer = opt.req("outer")?;
        let mini_models = j
            .arr_of("mini_ladder")?
            .iter()
            .map(|m| m.str_of("name"))
            .collect::<Result<Vec<_>>>()?;
        let paper_ladder = j
            .arr_of("paper_ladder")?
            .iter()
            .map(|m| {
                Ok(PaperModel {
                    name: m.str_of("name")?,
                    layers: m.usize_of("layers")?,
                    heads: m.usize_of("heads")?,
                    qkv_dim: m.usize_of("qkv_dim")?,
                    hidden_dim: m.usize_of("hidden_dim")?,
                    params: m.f64_of("params")?,
                    token_budget: m.f64_of("token_budget")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RepoConfig {
            root: root.to_path_buf(),
            vocab: tok.usize_of("vocab_size")?,
            seq_len: j.usize_of("seq_len")?,
            token_multiplier: j.f64_of("token_multiplier")?,
            mini_models,
            paper_ladder,
            optimizer: OptimizerPolicy {
                beta1: inner.f64_of("beta1")?,
                beta2: inner.f64_of("beta2")?,
                eps: inner.f64_of("eps")?,
                grad_clip: inner.f64_of("grad_clip")?,
                outer_momentum: outer.f64_of("momentum")?,
                warmup_frac: opt.f64_of("warmup_frac")?,
                warmup_cap: opt.usize_of("warmup_cap")?,
                final_lr_frac: opt.f64_of("final_lr_frac")?,
            },
            eval_batch: j.usize_of("eval_batch")?,
        })
    }

    pub fn artifacts_dir(&self) -> PathBuf {
        self.root.join("artifacts")
    }

    pub fn model_dir(&self, name: &str) -> PathBuf {
        self.artifacts_dir().join(name)
    }

    pub fn paper_model(&self, name: &str) -> Option<&PaperModel> {
        self.paper_ladder.iter().find(|m| m.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RepoConfig {
        RepoConfig::load(Path::new(env!("CARGO_MANIFEST_DIR"))).unwrap()
    }

    #[test]
    fn loads_and_validates() {
        let c = cfg();
        assert_eq!(c.vocab, 512);
        assert_eq!(c.seq_len, 64);
        assert_eq!(c.mini_models.len(), 5);
        assert_eq!(c.paper_ladder.len(), 9);
        assert!((c.token_multiplier - 20.0).abs() < 1e-9);
    }

    #[test]
    fn paper_ladder_chinchilla_budgets() {
        // Paper Table 3: token budget = 20 * params for every rung.
        for m in &cfg().paper_ladder {
            let ratio = m.token_budget / m.params;
            assert!((ratio - 20.0).abs() < 0.5, "{}: ratio {ratio}", m.name);
        }
    }

    #[test]
    fn optimizer_policy_matches_paper() {
        let o = cfg().optimizer;
        assert_eq!(o.beta1, 0.9);
        assert_eq!(o.beta2, 0.99);
        assert_eq!(o.outer_momentum, 0.9);
        assert_eq!(o.grad_clip, 1.0);
    }
}
