//! Minimal, complete JSON implementation (parser + writer).
//!
//! The offline sandbox has no serde/serde_json, so this module is the
//! repo's JSON substrate: artifact manifests, model configs, sweep
//! stores, and reports all flow through it. Supports the full JSON
//! grammar (nested containers, escapes incl. \uXXXX surrogate pairs,
//! scientific notation) and round-trips losslessly for the values we
//! produce (property-tested in `tests`).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic
/// serialization — reports and stores diff cleanly.
///
/// Integer literals parse into [`Json::Int`] (an `i128`, wide enough
/// for any `u64` seed) so values above 2^53 survive a round trip
/// without an `f64` detour; anything with a fraction or exponent stays
/// [`Json::Num`]. Equality treats `Int(5)` and `Num(5.0)` as equal, so
/// writers that format integral floats as integers still round-trip.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i128),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl PartialEq for Json {
    fn eq(&self, other: &Json) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::Int(a), Json::Int(b)) => a == b,
            // cross-variant: an integral Num equals an Int only when
            // the values match EXACTLY in both domains — the i128
            // round-trip keeps equality transitive when two distinct
            // Ints collide at f64 precision (above 2^53)
            (Json::Num(a), Json::Int(b)) | (Json::Int(b), Json::Num(a)) => {
                *a == *b as f64 && *a as i128 == *b
            }
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            _ => false,
        }
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ---------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(v: T) -> Json {
        Json::Num(v.into())
    }

    /// An exact integer (use for ids/seeds that must not pass through
    /// f64; `u64` and smaller all fit).
    pub fn int<T: Into<i128>>(v: T) -> Json {
        Json::Int(v.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that reports *which* key was missing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?} in {self}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            Json::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Int(v) => usize::try_from(*v).ok(),
            _ => self.as_f64().and_then(|v| {
                if v >= 0.0 && v.fract() == 0.0 && v <= u64::MAX as f64 {
                    Some(v as usize)
                } else {
                    None
                }
            }),
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(v) => i64::try_from(*v).ok(),
            _ => self.as_f64().and_then(|v| {
                if v.fract() == 0.0 && v.abs() <= i64::MAX as f64 {
                    Some(v as i64)
                } else {
                    None
                }
            }),
        }
    }

    /// Exact u64 access (seeds): `Int` round-trips all 64 bits; a
    /// legacy `Num` is accepted when it is a non-negative integer (the
    /// best a pre-Int store could have recorded).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Int(v) => u64::try_from(*v).ok(),
            _ => self.as_f64().and_then(|v| {
                if v >= 0.0 && v.fract() == 0.0 && v < u64::MAX as f64 {
                    Some(v as u64)
                } else {
                    None
                }
            }),
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // typed convenience with errors --------------------------------------
    pub fn f64_of(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not a number"))
    }

    pub fn usize_of(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not a non-negative integer"))
    }

    pub fn str_of(&self, key: &str) -> anyhow::Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not a string"))?
            .to_string())
    }

    pub fn u64_of(&self, key: &str) -> anyhow::Result<u64> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not a u64 integer"))
    }

    pub fn arr_of(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not an array"))
    }

    // ---- serialization ---------------------------------------------------
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_num(out, *v),
            Json::Int(v) => out.push_str(&v.to_string()),
            Json::Str(s) => write_string(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_string(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }

    pub fn write_file(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, v: f64) {
    if v.is_nan() || v.is_infinite() {
        // JSON has no NaN/Inf; encode as null (documented lossy case).
        out.push_str("null");
    } else if v == v.trunc() && v.abs() < 1e15 {
        out.push_str(&format!("{}", v as i64));
    } else {
        // Shortest roundtrip float formatting from std.
        out.push_str(&format!("{v}"));
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{08}'),
                        b'f' => out.push('\u{0c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("bad low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad unicode escape"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("raw control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        let mut is_float = false;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        if !is_float {
            // exact integer path (seeds > 2^53 must not pass through
            // f64); absurdly long digit strings overflow i128 and fall
            // back to the float path below.
            if let Ok(v) = s.parse::<i128>() {
                return Ok(Json::Int(v));
            }
        }
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.str_of("c").unwrap(), "x");
    }

    #[test]
    fn surrogate_pairs() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v, Json::Str("😀".into()));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v, Json::Str("héllo — ok".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\"}", "01x", "\"\\q\"", "nulll"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn integers_roundtrip_exactly() {
        // Seeds above 2^53 are exactly the values an f64 detour mangles.
        let big: u64 = (1u64 << 60) + 12345;
        assert_ne!((big as f64) as u64, big, "test value must exceed f64 precision");
        let j = Json::obj(vec![("seed", Json::int(big))]);
        let back = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(back.u64_of("seed").unwrap(), big);
        let max = Json::parse(&u64::MAX.to_string()).unwrap();
        assert_eq!(max.as_u64(), Some(u64::MAX));
        // fractions and exponents stay floats
        assert_eq!(Json::parse("2.0").unwrap(), Json::Num(2.0));
        assert_eq!(Json::parse("2e1").unwrap(), Json::Num(20.0));
    }

    #[test]
    fn int_num_cross_equality() {
        assert_eq!(Json::Num(5.0), Json::Int(5));
        assert_eq!(Json::Int(5), Json::Num(5.0));
        assert_ne!(Json::Num(5.5), Json::Int(5));
        // transitivity above 2^53: two Ints that collide at f64
        // precision stay distinct, and at most one equals the Num
        let a = (1i128 << 60) + 12345;
        let b = (1i128 << 60) + 12288; // = a rounded to f64
        assert_ne!(Json::Int(a), Json::Int(b));
        assert_eq!(Json::Num(b as f64), Json::Int(b));
        assert_ne!(Json::Num(b as f64), Json::Int(a));
        assert_eq!(Json::parse("7").unwrap(), Json::Num(7.0));
        // integer accessors prefer the exact path
        assert_eq!(Json::Int(-3).as_i64(), Some(-3));
        assert_eq!(Json::Int(-3).as_usize(), None);
        assert_eq!(Json::Int(9).as_f64(), Some(9.0));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Json::obj(vec![
            ("nums", Json::arr((0..5).map(|i| Json::num(i as f64 * 0.5)))),
            ("s", Json::str("line\n\"quoted\"\t")),
            ("nested", Json::obj(vec![("x", Json::Null)])),
        ]);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }
}
