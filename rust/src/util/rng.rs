//! Deterministic RNG substrate (splitmix64 + xoshiro256**).
//!
//! Every stochastic component in the coordinator (data generation,
//! sharding, sweep jitter, fit restarts) draws from this, keyed by an
//! explicit u64 seed, so entire experiments replay bit-identically —
//! a requirement for the paper's sweep methodology and for the test
//! suite's golden values. (The `rand` crate is unavailable offline.)

/// splitmix64 — used to seed xoshiro and to derive child seeds.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (e.g. per replica / per shard).
    pub fn child(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn child_streams_independent() {
        let root = Rng::new(7);
        let mut c0 = root.child(0);
        let mut c1 = root.child(1);
        assert_ne!(c0.next_u64(), c1.next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[rng.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(counts[2] > counts[0] * 4);
    }
}
