//! Deterministic range sharding + scoped fork-join, the substrate for
//! the parallel outer sync (codec encode/decode, fused reduce, outer
//! step). Stands in for rayon, which is unavailable in the offline
//! sandbox.
//!
//! The bit-identity rule: every f32 operation on a given element must
//! run in the same order regardless of thread count. [`shard_ranges`]
//! guarantees that by cutting the source ranges into contiguous,
//! block-aligned pieces with deterministic ownership — each element
//! belongs to exactly one shard, so its whole op sequence (zero,
//! decode-add per replica in replica-index order, finish, step) runs
//! on one thread in the same order as the sequential path. Summation
//! order never changes; only which thread performs it does.

use std::ops::Range;

/// One contiguous piece of a source range. `src` indexes the slice of
/// ranges passed to [`shard_ranges`]; the piece's wire/RNG position
/// within that range follows from `range.start - ranges[src].start`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Piece {
    pub src: usize,
    pub range: Range<usize>,
}

impl Piece {
    pub fn len(&self) -> usize {
        self.range.len()
    }

    pub fn is_empty(&self) -> bool {
        self.range.is_empty()
    }
}

/// Cut `ranges` into at most `threads` shards of contiguous pieces,
/// each piece `align`-aligned relative to its source range's start
/// (so codec blocks never straddle a cut; only the final piece of a
/// range may end off-alignment). Pieces never span source ranges —
/// each range has its own wire stream and RNG seed. The partition is
/// a pure function of `(ranges, threads, align)`: deterministic,
/// ordered, disjoint, and covering.
pub fn shard_ranges(ranges: &[Range<usize>], threads: usize, align: usize) -> Vec<Vec<Piece>> {
    let align = align.max(1);
    let mut units: Vec<Piece> = Vec::new();
    for (src, r) in ranges.iter().enumerate() {
        let mut start = r.start;
        while start < r.end {
            let end = (start + align).min(r.end);
            units.push(Piece { src, range: start..end });
            start = end;
        }
    }
    let total = units.len();
    let t = threads.max(1).min(total.max(1));
    let mut shards: Vec<Vec<Piece>> = Vec::with_capacity(t);
    let mut iter = units.into_iter();
    for s in 0..t {
        let take = (s + 1) * total / t - s * total / t;
        let mut shard: Vec<Piece> = Vec::new();
        for _ in 0..take {
            let u = iter.next().expect("unit budget covers all units");
            match shard.last_mut() {
                // fuse adjacent units of the same source range back
                // into one long piece (fewer kernel calls per shard)
                Some(last) if last.src == u.src && last.range.end == u.range.start => {
                    last.range.end = u.range.end;
                }
                _ => shard.push(u),
            }
        }
        shards.push(shard);
    }
    shards
}

/// Split one mutable arena into per-shard, per-piece disjoint views.
/// Pieces are globally ascending and disjoint by construction
/// ([`shard_ranges`]), so successive `split_at_mut` walks cover them
/// without aliasing.
pub fn split_pieces<'a, T>(data: &'a mut [T], shards: &[Vec<Piece>]) -> Vec<Vec<&'a mut [T]>> {
    let mut rest = data;
    let mut base = 0usize;
    let mut out = Vec::with_capacity(shards.len());
    for shard in shards {
        let mut views = Vec::with_capacity(shard.len());
        for p in shard {
            let skip = p.range.start - base;
            let tail = std::mem::take(&mut rest);
            let (seg, tail) = tail[skip..].split_at_mut(p.len());
            views.push(seg);
            rest = tail;
            base = p.range.end;
        }
        out.push(views);
    }
    out
}

/// Fork-join map over per-shard work items: one scoped thread per
/// item, results in item order. A single item (or none) runs inline —
/// `threads = 1` is structurally the sequential path, not a
/// one-thread pool. Panics in any shard propagate at scope exit.
pub fn map_shards<W, R, F>(items: Vec<W>, f: F) -> Vec<R>
where
    W: Send,
    R: Send,
    F: Fn(usize, W) -> R + Sync,
{
    if items.len() <= 1 {
        return items.into_iter().enumerate().map(|(i, w)| f(i, w)).collect();
    }
    let n = items.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (i, (item, slot)) in items.into_iter().zip(slots.iter_mut()).enumerate() {
            let f = &f;
            s.spawn(move || {
                *slot = Some(f(i, item));
            });
        }
    });
    slots
        .into_iter()
        .map(|o| o.expect("every shard thread writes its slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flatten(shards: &[Vec<Piece>]) -> Vec<Piece> {
        shards.iter().flatten().cloned().collect()
    }

    #[test]
    fn shards_cover_disjoint_ordered_and_aligned() {
        let ranges = vec![0..700, 700..900, 1000..1001, 1100..1612];
        for threads in [1, 2, 3, 7, 64] {
            let shards = shard_ranges(&ranges, threads, 256);
            assert!(shards.len() <= threads.max(1));
            let pieces = flatten(&shards);
            // ascending, disjoint, never spanning source ranges
            let mut last_end = 0usize;
            for p in &pieces {
                assert!(p.range.start >= last_end, "{threads}: {pieces:?}");
                assert!(p.range.start >= ranges[p.src].start);
                assert!(p.range.end <= ranges[p.src].end);
                // interior cuts land on block boundaries
                let off = p.range.start - ranges[p.src].start;
                assert_eq!(off % 256, 0, "{threads}: piece {p:?} misaligned");
                last_end = p.range.end;
            }
            // covering: total length matches
            let want: usize = ranges.iter().map(|r| r.len()).sum();
            let got: usize = pieces.iter().map(|p| p.len()).sum();
            assert_eq!(got, want, "threads={threads}");
            // deterministic
            assert_eq!(shards, shard_ranges(&ranges, threads, 256));
        }
    }

    #[test]
    fn single_thread_is_one_piece_per_range() {
        let ranges = vec![3..600, 600..640];
        let shards = shard_ranges(&ranges, 1, 256);
        assert_eq!(shards.len(), 1);
        assert_eq!(
            shards[0],
            vec![Piece { src: 0, range: 3..600 }, Piece { src: 1, range: 600..640 }]
        );
    }

    #[test]
    fn empty_ranges_yield_one_empty_shard() {
        let shards = shard_ranges(&[], 8, 256);
        assert_eq!(shards, vec![Vec::<Piece>::new()]);
        let shards = shard_ranges(&[5..5], 8, 256);
        assert_eq!(flatten(&shards), vec![]);
    }

    #[test]
    fn split_pieces_views_are_disjoint_and_correct() {
        let ranges = vec![0..500, 500..1000];
        let shards = shard_ranges(&ranges, 3, 256);
        let mut data: Vec<usize> = (0..1000).collect();
        let views = split_pieces(&mut data, &shards);
        assert_eq!(views.len(), shards.len());
        for (shard, vs) in shards.iter().zip(&views) {
            for (p, v) in shard.iter().zip(vs) {
                assert_eq!(v.len(), p.len());
                assert_eq!(v[0], p.range.start);
                assert_eq!(*v.last().unwrap(), p.range.end - 1);
            }
        }
    }

    #[test]
    fn map_shards_matches_inline_and_propagates_order() {
        let items: Vec<usize> = (0..7).collect();
        let seq = map_shards(items.clone(), |i, w| i * 1000 + w * w);
        assert_eq!(seq.len(), 7);
        for (i, &r) in seq.iter().enumerate() {
            assert_eq!(r, i * 1000 + i * i);
        }
        // single item runs inline (no thread spawn): same contract
        assert_eq!(map_shards(vec![9usize], |i, w| (i, w)), vec![(0, 9)]);
        assert_eq!(map_shards(Vec::<usize>::new(), |_, w| w), vec![]);
    }
}
