//! Small numerical/statistics substrate: summaries, linear least squares
//! (normal equations with multiple regressors), quantiles. Used by the
//! scaling-law fitters and the bench harness.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// p-quantile (0..=1) by linear interpolation on a copy.
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (idx - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Ordinary least squares: finds beta minimizing ||X beta - y||^2,
/// where `rows[i]` is the i-th row of X (len = k). Solves the k x k
/// normal equations by Gaussian elimination with partial pivoting.
/// Returns None if the system is singular.
pub fn least_squares(rows: &[Vec<f64>], y: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(rows.len(), y.len());
    if rows.is_empty() {
        return None;
    }
    let k = rows[0].len();
    // Build X^T X and X^T y.
    let mut a = vec![vec![0.0; k]; k];
    let mut b = vec![0.0; k];
    for (row, &yi) in rows.iter().zip(y) {
        assert_eq!(row.len(), k);
        for i in 0..k {
            b[i] += row[i] * yi;
            for j in 0..k {
                a[i][j] += row[i] * row[j];
            }
        }
    }
    solve_linear(&mut a, &mut b)
}

/// Solve A x = b in place; returns None if singular.
pub fn solve_linear(a: &mut [Vec<f64>], b: &mut [f64]) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // partial pivot
        let mut pivot = col;
        for r in col + 1..n {
            if a[r][col].abs() > a[pivot][col].abs() {
                pivot = r;
            }
        }
        if a[pivot][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        let div = a[col][col];
        for j in col..n {
            a[col][j] /= div;
        }
        b[col] /= div;
        for r in 0..n {
            if r != col && a[r][col] != 0.0 {
                let f = a[r][col];
                for j in col..n {
                    a[r][j] -= f * a[col][j];
                }
                b[r] -= f * b[col];
            }
        }
    }
    Some(b.to_vec())
}

/// Simple linear regression y = a + b x; returns (a, b).
pub fn linreg(x: &[f64], y: &[f64]) -> Option<(f64, f64)> {
    let rows: Vec<Vec<f64>> = x.iter().map(|&xi| vec![1.0, xi]).collect();
    let beta = least_squares(&rows, y)?;
    Some((beta[0], beta[1]))
}

/// Fit a quadratic y = c0 + c1 x + c2 x^2; returns [c0, c1, c2].
pub fn quadfit(x: &[f64], y: &[f64]) -> Option<Vec<f64>> {
    let rows: Vec<Vec<f64>> = x.iter().map(|&xi| vec![1.0, xi, xi * xi]).collect();
    least_squares(&rows, y)
}

/// Huber loss with parameter delta (the paper's parametric-fit objective).
pub fn huber(delta: f64, r: f64) -> f64 {
    let a = r.abs();
    if a <= delta {
        0.5 * r * r
    } else {
        delta * (a - 0.5 * delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
    }

    #[test]
    fn linreg_exact() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linreg(&x, &y).unwrap();
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn least_squares_two_regressors() {
        // y = 2 + 3u - 0.5v on a grid, recovered exactly.
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for u in 0..4 {
            for v in 0..4 {
                rows.push(vec![1.0, u as f64, v as f64]);
                y.push(2.0 + 3.0 * u as f64 - 0.5 * v as f64);
            }
        }
        let beta = least_squares(&rows, &y).unwrap();
        for (got, want) in beta.iter().zip([2.0, 3.0, -0.5]) {
            assert!((got - want).abs() < 1e-9);
        }
    }

    #[test]
    fn singular_detected() {
        let rows = vec![vec![1.0, 2.0], vec![2.0, 4.0], vec![3.0, 6.0]];
        let y = vec![1.0, 2.0, 3.0];
        assert!(least_squares(&rows, &y).is_none());
    }

    #[test]
    fn quad_exact() {
        let x: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&x| 1.0 - 2.0 * x + 0.5 * x * x).collect();
        let c = quadfit(&x, &y).unwrap();
        assert!((c[0] - 1.0).abs() < 1e-9);
        assert!((c[1] + 2.0).abs() < 1e-9);
        assert!((c[2] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn huber_regimes() {
        assert_eq!(huber(1.0, 0.5), 0.125);
        assert_eq!(huber(1.0, 2.0), 1.5); // delta*(|r|-delta/2)
    }
}
