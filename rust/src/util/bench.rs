//! Bench harness (criterion is unavailable offline).
//!
//! `[[bench]] harness = false` targets call [`Bencher::run`] per case:
//! warmup, then timed iterations until a wall budget or max-iter cap,
//! reporting min/median/p95/mean. Output is a fixed-width table so
//! `cargo bench | tee bench_output.txt` reads like a report, and
//! [`Bencher::write_json`] emits the same numbers machine-readably
//! (`BENCH_*.json`) so the perf trajectory is recorded across PRs.

use std::time::{Duration, Instant};

use crate::util::json::Json;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub mean: Duration,
    /// Bytes moved per iteration (set via [`Bencher::run_throughput`])
    /// — reported as GiB/s off the median.
    pub bytes: Option<u64>,
    /// Elements processed per iteration — reported as Melem/s.
    pub elems: Option<u64>,
}

impl BenchResult {
    /// Median-based throughput in GiB/s, when the case declared bytes.
    pub fn gib_per_s(&self) -> Option<f64> {
        let b = self.bytes?;
        let s = self.median.as_secs_f64();
        (s > 0.0).then(|| b as f64 / (1u64 << 30) as f64 / s)
    }

    /// Median-based throughput in Melem/s, when the case declared elems.
    pub fn melem_per_s(&self) -> Option<f64> {
        let e = self.elems?;
        let s = self.median.as_secs_f64();
        (s > 0.0).then(|| e as f64 / 1e6 / s)
    }
}

pub struct Bencher {
    pub warmup_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
    results: Vec<BenchResult>,
    /// Non-timing side tables (e.g. exact wire-byte counts) attached
    /// to the JSON report alongside `results`. `diff_reports` ignores
    /// them — they carry context, not regression-gated numbers.
    extras: Vec<(String, Json)>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 2,
            max_iters: 50,
            budget: Duration::from_secs(5),
            results: Vec::new(),
            extras: Vec::new(),
        }
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

impl Bencher {
    pub fn new(budget_secs: f64) -> Bencher {
        Bencher {
            budget: Duration::from_secs_f64(budget_secs),
            ..Default::default()
        }
    }

    /// Time `f` and record a row. The closure should return something
    /// observable to keep the optimizer honest; its value is black-boxed.
    pub fn run<T>(&mut self, name: &str, f: impl FnMut() -> T) {
        self.run_case(name, None, None, f);
    }

    /// Like [`Bencher::run`] for a case that moves `bytes` bytes and
    /// processes `elems` elements per iteration: the report adds GiB/s
    /// and Melem/s columns computed off the median, so memory-bound
    /// kernels read directly against machine bandwidth.
    pub fn run_throughput<T>(
        &mut self,
        name: &str,
        bytes: u64,
        elems: u64,
        f: impl FnMut() -> T,
    ) {
        self.run_case(name, Some(bytes), Some(elems), f);
    }

    fn run_case<T>(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        elems: Option<u64>,
        mut f: impl FnMut() -> T,
    ) {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_iters
            && (samples.len() < 3 || start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: n,
            min: samples[0],
            median: samples[n / 2],
            p95: samples[(n as f64 * 0.95) as usize % n],
            mean: total / n as u32,
            bytes,
            elems,
        });
    }

    /// Print the result table; call once at the end of a bench binary.
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>6} {:>12} {:>12} {:>12} {:>12} {:>9} {:>9}",
            "benchmark", "iters", "min", "median", "p95", "mean", "GiB/s", "Melem/s"
        );
        let fmt_rate = |r: Option<f64>| match r {
            Some(v) if v >= 100.0 => format!("{v:.0}"),
            Some(v) => format!("{v:.2}"),
            None => "-".into(),
        };
        for r in &self.results {
            println!(
                "{:<44} {:>6} {:>12} {:>12} {:>12} {:>12} {:>9} {:>9}",
                r.name,
                r.iters,
                fmt_dur(r.min),
                fmt_dur(r.median),
                fmt_dur(r.p95),
                fmt_dur(r.mean),
                fmt_rate(r.gib_per_s()),
                fmt_rate(r.melem_per_s()),
            );
        }
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Attach (or replace) a non-timing side table emitted under the
    /// given top-level key in the JSON report. The core report keys
    /// are reserved — a duplicate would shadow the timing results.
    pub fn extra(&mut self, key: &str, value: Json) {
        assert!(
            key != "title" && key != "results",
            "bench extra key {key:?} would collide with the report schema"
        );
        if let Some(slot) = self.extras.iter_mut().find(|(k, _)| k == key) {
            slot.1 = value;
        } else {
            self.extras.push((key.to_string(), value));
        }
    }

    /// The result table as JSON (nanosecond integers — exact, no f64).
    pub fn to_json(&self, title: &str) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("title", Json::str(title)),
            (
                "results",
                Json::arr(self.results.iter().map(|r| {
                    let mut fields = vec![
                        ("name", Json::str(&r.name)),
                        ("iters", Json::int(r.iters as i128)),
                        ("min_ns", Json::int(r.min.as_nanos() as i128)),
                        ("median_ns", Json::int(r.median.as_nanos() as i128)),
                        ("p95_ns", Json::int(r.p95.as_nanos() as i128)),
                        ("mean_ns", Json::int(r.mean.as_nanos() as i128)),
                    ];
                    if let Some(b) = r.bytes {
                        fields.push(("bytes", Json::int(b as i128)));
                    }
                    if let Some(e) = r.elems {
                        fields.push(("elems", Json::int(e as i128)));
                    }
                    Json::obj(fields)
                })),
            ),
        ];
        for (k, v) in &self.extras {
            fields.push((k.as_str(), v.clone()));
        }
        Json::obj(fields)
    }

    /// Write the machine-readable result file (e.g. `BENCH_hot_path.json`).
    pub fn write_json(&self, path: &std::path::Path, title: &str) -> anyhow::Result<()> {
        self.to_json(title).write_file(path)
    }
}

// ---- bench report diffing (perf trend tracking across commits) -------

/// One case's before/after medians. A side is `None` when the case
/// only exists in the other report (added/removed benchmarks).
#[derive(Debug, Clone, PartialEq)]
pub struct CaseDelta {
    pub name: String,
    pub old_median_ns: Option<u64>,
    pub new_median_ns: Option<u64>,
}

impl CaseDelta {
    /// Relative median change in percent (positive = slower). `None`
    /// unless the case is present on both sides.
    pub fn delta_pct(&self) -> Option<f64> {
        match (self.old_median_ns, self.new_median_ns) {
            (Some(o), Some(n)) if o > 0 => Some((n as f64 - o as f64) / o as f64 * 100.0),
            _ => None,
        }
    }
}

fn case_medians(report: &Json) -> anyhow::Result<Vec<(String, u64)>> {
    report
        .arr_of("results")?
        .iter()
        .map(|r| Ok((r.str_of("name")?, r.u64_of("median_ns")?)))
        .collect()
}

/// Diff two bench reports (the JSON emitted by [`Bencher::write_json`]):
/// new-report case order first, then cases that were removed. This is
/// what `diloco bench-diff` and `cargo bench -- --diff OLD.json` print
/// so perf regressions surface in review.
pub fn diff_reports(old: &Json, new: &Json) -> anyhow::Result<Vec<CaseDelta>> {
    let old_cases = case_medians(old)?;
    let new_cases = case_medians(new)?;
    let old_by_name: std::collections::BTreeMap<&str, u64> = old_cases
        .iter()
        .map(|(n, m)| (n.as_str(), *m))
        .collect();
    let new_names: std::collections::BTreeSet<&str> =
        new_cases.iter().map(|(n, _)| n.as_str()).collect();
    let mut out: Vec<CaseDelta> = new_cases
        .iter()
        .map(|(name, m)| CaseDelta {
            name: name.clone(),
            old_median_ns: old_by_name.get(name.as_str()).copied(),
            new_median_ns: Some(*m),
        })
        .collect();
    for (name, m) in &old_cases {
        if !new_names.contains(name.as_str()) {
            out.push(CaseDelta {
                name: name.clone(),
                old_median_ns: Some(*m),
                new_median_ns: None,
            });
        }
    }
    Ok(out)
}

/// Print per-case deltas as a fixed-width table (medians; `new` /
/// `gone` mark cases present on only one side).
pub fn print_diff(deltas: &[CaseDelta]) {
    println!(
        "{:<52} {:>12} {:>12} {:>9}",
        "benchmark", "old median", "new median", "delta"
    );
    let fmt_opt = |ns: Option<u64>| match ns {
        Some(v) => fmt_dur(Duration::from_nanos(v)),
        None => "-".into(),
    };
    for d in deltas {
        let delta = match d.delta_pct() {
            Some(p) => format!("{p:+.1}%"),
            None if d.old_median_ns.is_none() => "new".into(),
            None => "gone".into(),
        };
        println!(
            "{:<52} {:>12} {:>12} {:>9}",
            d.name,
            fmt_opt(d.old_median_ns),
            fmt_opt(d.new_median_ns),
            delta
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut b = Bencher::new(0.05);
        b.run("noop", || 1 + 1);
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].iters >= 3);
    }

    #[test]
    fn json_emission_shape() {
        let mut b = Bencher::new(0.05);
        b.run("case", || 2 * 2);
        let j = b.to_json("hot path");
        assert_eq!(j.str_of("title").unwrap(), "hot path");
        let rs = j.arr_of("results").unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].str_of("name").unwrap(), "case");
        assert!(rs[0].u64_of("median_ns").is_ok());
        assert!(rs[0].u64_of("iters").unwrap() >= 3);
    }

    #[test]
    fn throughput_cases_carry_rates_into_json() {
        let mut b = Bencher::new(0.05);
        b.run("plain", || 1 + 1);
        b.run_throughput("bulk", 2 * (1 << 30), 4_000_000, || 2 * 2);
        let plain = &b.results()[0];
        assert_eq!(plain.bytes, None);
        assert_eq!(plain.gib_per_s(), None);
        let bulk = &b.results()[1];
        assert_eq!(bulk.bytes, Some(2 * (1 << 30)));
        assert!(bulk.gib_per_s().unwrap() > 0.0);
        assert!(bulk.melem_per_s().unwrap() > 0.0);
        let j = b.to_json("t");
        let rs = j.arr_of("results").unwrap();
        assert!(rs[0].get("bytes").is_none(), "plain cases omit the fields");
        assert_eq!(rs[1].u64_of("bytes").unwrap(), 2 * (1 << 30));
        assert_eq!(rs[1].u64_of("elems").unwrap(), 4_000_000);
        // the diff gate keys on name/median only — extras never break it
        assert_eq!(diff_reports(&j, &j).unwrap().len(), 2);
        b.report("t"); // rate columns must format without panicking
    }

    #[test]
    fn format_durations() {
        assert_eq!(fmt_dur(Duration::from_nanos(10)), "10 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }

    fn report(cases: &[(&str, u64)]) -> Json {
        Json::obj(vec![
            ("title", Json::str("t")),
            (
                "results",
                Json::arr(cases.iter().map(|(n, m)| {
                    Json::obj(vec![
                        ("name", Json::str(n)),
                        ("iters", Json::int(5)),
                        ("min_ns", Json::int(*m as i128)),
                        ("median_ns", Json::int(*m as i128)),
                        ("p95_ns", Json::int(*m as i128)),
                        ("mean_ns", Json::int(*m as i128)),
                    ])
                })),
            ),
        ])
    }

    #[test]
    fn diff_matches_adds_and_removes() {
        let old = report(&[("a", 100), ("b", 200), ("gone", 40)]);
        let new = report(&[("a", 150), ("b", 100), ("fresh", 70)]);
        let d = diff_reports(&old, &new).unwrap();
        assert_eq!(d.len(), 4);
        assert_eq!(d[0].name, "a");
        assert_eq!(d[0].delta_pct(), Some(50.0));
        assert_eq!(d[1].delta_pct(), Some(-50.0));
        assert_eq!(d[2].name, "fresh");
        assert_eq!(d[2].old_median_ns, None);
        assert_eq!(d[2].delta_pct(), None);
        assert_eq!(d[3].name, "gone");
        assert_eq!(d[3].new_median_ns, None);
        print_diff(&d); // formatting must not panic
    }

    #[test]
    fn diff_roundtrips_through_bencher_json() {
        let mut b = Bencher::new(0.05);
        b.run("case", || 2 * 2);
        let j = b.to_json("hot path");
        let d = diff_reports(&j, &j).unwrap();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].delta_pct(), Some(0.0));
    }

    #[test]
    fn diff_rejects_malformed_reports() {
        assert!(diff_reports(&Json::obj(vec![]), &report(&[])).is_err());
    }

    #[test]
    fn extras_ride_along_without_breaking_diffs() {
        let mut b = Bencher::new(0.05);
        b.run("case", || 2 * 2);
        b.extra("wire_bytes", Json::obj(vec![("n", Json::int(5))]));
        b.extra("wire_bytes", Json::obj(vec![("n", Json::int(6))])); // replaces
        let j = b.to_json("t");
        assert_eq!(j.req("wire_bytes").unwrap().u64_of("n").unwrap(), 6);
        // diffing a report that carries extras still works on results
        let d = diff_reports(&j, &j).unwrap();
        assert_eq!(d.len(), 1);
    }
}
