//! Bench harness (criterion is unavailable offline).
//!
//! `[[bench]] harness = false` targets call [`Bencher::run`] per case:
//! warmup, then timed iterations until a wall budget or max-iter cap,
//! reporting min/median/p95/mean. Output is a fixed-width table so
//! `cargo bench | tee bench_output.txt` reads like a report, and
//! [`Bencher::write_json`] emits the same numbers machine-readably
//! (`BENCH_*.json`) so the perf trajectory is recorded across PRs.

use std::time::{Duration, Instant};

use crate::util::json::Json;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub mean: Duration,
}

pub struct Bencher {
    pub warmup_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 2,
            max_iters: 50,
            budget: Duration::from_secs(5),
            results: Vec::new(),
        }
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

impl Bencher {
    pub fn new(budget_secs: f64) -> Bencher {
        Bencher {
            budget: Duration::from_secs_f64(budget_secs),
            ..Default::default()
        }
    }

    /// Time `f` and record a row. The closure should return something
    /// observable to keep the optimizer honest; its value is black-boxed.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_iters
            && (samples.len() < 3 || start.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: n,
            min: samples[0],
            median: samples[n / 2],
            p95: samples[(n as f64 * 0.95) as usize % n],
            mean: total / n as u32,
        });
    }

    /// Print the result table; call once at the end of a bench binary.
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>6} {:>12} {:>12} {:>12} {:>12}",
            "benchmark", "iters", "min", "median", "p95", "mean"
        );
        for r in &self.results {
            println!(
                "{:<44} {:>6} {:>12} {:>12} {:>12} {:>12}",
                r.name,
                r.iters,
                fmt_dur(r.min),
                fmt_dur(r.median),
                fmt_dur(r.p95),
                fmt_dur(r.mean)
            );
        }
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// The result table as JSON (nanosecond integers — exact, no f64).
    pub fn to_json(&self, title: &str) -> Json {
        Json::obj(vec![
            ("title", Json::str(title)),
            (
                "results",
                Json::arr(self.results.iter().map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(&r.name)),
                        ("iters", Json::int(r.iters as i128)),
                        ("min_ns", Json::int(r.min.as_nanos() as i128)),
                        ("median_ns", Json::int(r.median.as_nanos() as i128)),
                        ("p95_ns", Json::int(r.p95.as_nanos() as i128)),
                        ("mean_ns", Json::int(r.mean.as_nanos() as i128)),
                    ])
                })),
            ),
        ])
    }

    /// Write the machine-readable result file (e.g. `BENCH_hot_path.json`).
    pub fn write_json(&self, path: &std::path::Path, title: &str) -> anyhow::Result<()> {
        self.to_json(title).write_file(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut b = Bencher::new(0.05);
        b.run("noop", || 1 + 1);
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].iters >= 3);
    }

    #[test]
    fn json_emission_shape() {
        let mut b = Bencher::new(0.05);
        b.run("case", || 2 * 2);
        let j = b.to_json("hot path");
        assert_eq!(j.str_of("title").unwrap(), "hot path");
        let rs = j.arr_of("results").unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].str_of("name").unwrap(), "case");
        assert!(rs[0].u64_of("median_ns").is_ok());
        assert!(rs[0].u64_of("iters").unwrap() >= 3);
    }

    #[test]
    fn format_durations() {
        assert_eq!(fmt_dur(Duration::from_nanos(10)), "10 ns");
        assert!(fmt_dur(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
