//! Shared substrates: JSON, RNG, statistics, bench harness,
//! property-testing kit, deterministic sharding, logging. These stand
//! in for serde/rand/criterion/proptest/rayon, which are unavailable
//! in the offline sandbox (DESIGN.md section 7).

pub mod bench;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;

/// Tiny stderr logger honoring RUST_LOG=debug|info|warn|error.
pub struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _: &log::Metadata) -> bool {
        true
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{:<5}] {}", record.level(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; safe to call repeatedly.
pub fn init_logging() {
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER).map(|_| log::set_max_level(level));
}
