//! Property-testing kit (proptest is unavailable offline).
//!
//! A seeded case runner: generate `cases` random inputs from a closure
//! over [`Rng`], assert the property on each, and on failure report the
//! seed + case index so the exact case replays deterministically.
//! Used across coordinator/scaling/json/data tests.

use super::rng::Rng;

pub const DEFAULT_CASES: usize = 128;

/// Run `property` on `cases` generated inputs. Panics (with replay info)
/// on the first failing case.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut property: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let root = Rng::new(seed);
    for case in 0..cases {
        let mut rng = root.child(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = property(&input) {
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Convenience: assert two f64s are close (absolute + relative).
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol}, scaled {})", tol * scale))
    }
}

/// Convenience: assert slices are element-wise close.
pub fn all_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("index {i}: {x} != {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(
            1,
            64,
            |rng| rng.below(100),
            |&v| {
                if v < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure_with_seed() {
        check(2, 64, |rng| rng.below(10), |&v| {
            if v < 5 {
                Ok(())
            } else {
                Err(format!("{v} >= 5"))
            }
        });
    }

    #[test]
    fn close_scales() {
        assert!(close(1e9, 1e9 + 10.0, 1e-6).is_ok());
        assert!(close(1.0, 1.1, 1e-6).is_err());
    }
}
