//! # diloco — Scaling Laws for DiLoCo (reproduction)
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *Communication-Efficient Language Model Training Scales Reliably and
//! Robustly: Scaling Laws for DiLoCo* (Charles et al., NeurIPS 2025).
//!
//! - Layer 3 (this crate): DiLoCo coordinator (Algorithm 1), sweep
//!   harness, scaling-law fitting, analytic network simulators, report
//!   generation.
//! - Layer 2 (python/compile, build-time only): JAX transformer fwd/bwd
//!   + AdamW, lowered once to HLO text artifacts.
//! - Layer 1 (python/compile/kernels): Pallas flash-attention and fused
//!   AdamW kernels inside the lowered HLO.
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod netsim;
pub mod report;
pub mod scaling;
pub mod runtime;
pub mod sweep;
pub mod train;
pub mod transport;
pub mod util;
