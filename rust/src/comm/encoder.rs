//! Replica-side sync encoding: where the quantize half of the
//! quantize→reduce→dequantize contract runs.
//!
//! A [`SyncEncoder`] is the immutable recipe (layout + codec +
//! fragment count + run seed), shared by every pool worker; a
//! [`CommState`] is one replica's mutable comm memory — pull scratch,
//! the global-parameter snapshot from the last broadcast, and the
//! error-feedback residual — owned by the replica's worker thread for
//! the whole run, exactly like its data shard.
//!
//! Per sync event, for the due fragment's ranges:
//!
//! 1. pull — the replica's current parameter literals are read into
//!    the scratch arena (device→host edge of the wire);
//! 2. identity codec: the raw f32 parameters are the payload (the
//!    legacy wire, bit for bit);
//!    lossy codec: the payload is the **error-compensated outer
//!    delta** `x = (global_snap - theta) + residual`, encoded with the
//!    per-range seed, after which `residual <- x - decode(encode(x))`
//!    carries this sync's quantization error into the next one
//!    (error feedback makes the quantized outer step unbiased over
//!    repeated syncs instead of silently losing mass);
//! 3. the encoded bytes travel to the coordinator over the pool
//!    channel — nothing else does for a DiLoCo sync.
//!
//! # Determinism rules
//!
//! The payload bytes are a pure function of (codec, run seed, sync
//! index, replica id, range offsets, replica values). Worker count,
//! thread scheduling, and wall-clock never enter: seeds are derived
//! per `(sync_index, replica, range.start)` via splitmix chains, and
//! the residual/snapshot state advances only with the replica's own
//! sync sequence. This is what lets `tests/comm_codec.rs` pin workers
//! 1 vs 4 bit-identical at every bit width.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::FlatLayout;
use crate::util::rng::splitmix64;

use super::codec::Codec;

/// One replica's mutable comm-side state. Arenas are lazily sized to
/// the layout; lossy codecs additionally need [`SyncEncoder::init_snapshot`]
/// before the first sync.
#[derive(Default)]
pub struct CommState {
    /// Device→host pull arena (all codecs).
    scratch: Vec<f32>,
    /// Global params as of the last broadcast (lossy codecs only).
    snap: Vec<f32>,
    /// Error-feedback residual (lossy codecs only).
    residual: Vec<f32>,
    /// `delta + residual` staging (lossy codecs only).
    staging: Vec<f32>,
}

impl CommState {
    /// The error-feedback residual arena (empty until the first lossy
    /// sync) — exposed for tests.
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }
}

/// The shared encoding recipe for one training run.
#[derive(Clone)]
pub struct SyncEncoder {
    layout: Arc<FlatLayout>,
    codec: Arc<dyn Codec>,
    fragments: usize,
    run_seed: u64,
}

impl SyncEncoder {
    pub fn new(
        layout: Arc<FlatLayout>,
        codec: Arc<dyn Codec>,
        fragments: usize,
        run_seed: u64,
    ) -> SyncEncoder {
        SyncEncoder {
            layout,
            codec,
            fragments: fragments.max(1),
            run_seed,
        }
    }

    pub fn codec(&self) -> &Arc<dyn Codec> {
        &self.codec
    }

    /// Exact payload size of one replica's contribution to a sync of
    /// `frag` (what every worker will put on the channel).
    pub fn payload_bytes(&self, frag: Option<usize>) -> usize {
        self.ranges(frag)
            .iter()
            .map(|r| self.codec.wire_bytes(r.len()))
            .sum()
    }

    fn ranges(&self, frag: Option<usize>) -> Vec<std::ops::Range<usize>> {
        match frag {
            Some(f) => self.layout.fragment_ranges(self.fragments, f),
            None => self.layout.full_range(),
        }
    }

    /// Deterministic encode seed: pure in (run seed, sync index,
    /// replica, range offset) — never scheduling.
    fn seed_for(&self, sync_index: u64, rep: usize, range_start: usize) -> u64 {
        let mut s = self.run_seed ^ 0x5EED_C0DE_u64;
        let a = splitmix64(&mut s);
        let mut s = a ^ sync_index;
        let b = splitmix64(&mut s);
        let mut s = b ^ ((rep as u64) << 32) ^ range_start as u64;
        splitmix64(&mut s)
    }

    /// Capture the sync'd global params from the replica's state
    /// literals (call once before the first inner step, when replica
    /// state still equals the global init — Algorithm 1 line 2). No-op
    /// for identity codecs, which never form deltas.
    pub fn init_snapshot(
        &self,
        comm: &mut CommState,
        state: &[Arc<xla::Literal>],
    ) -> Result<()> {
        if self.codec.is_identity() {
            return Ok(());
        }
        let total = self.layout.total();
        comm.snap = vec![0.0; total];
        comm.residual = vec![0.0; total];
        comm.staging = vec![0.0; total];
        for leaf in 0..self.layout.n_leaves() {
            let r = self.layout.range(leaf);
            state[leaf]
                .to_slice::<f32>(&mut comm.snap[r])
                .map_err(|e| anyhow::anyhow!("comm snapshot: leaf {leaf}: {e}"))?;
        }
        Ok(())
    }

    /// Refresh the global snapshot from a broadcast's adopt list
    /// (synced leaves only; untouched leaves keep their values).
    pub fn adopt(
        &self,
        comm: &mut CommState,
        adopt: &[(usize, Arc<xla::Literal>)],
    ) -> Result<()> {
        if self.codec.is_identity() || adopt.is_empty() {
            return Ok(());
        }
        if comm.snap.is_empty() && self.layout.total() > 0 {
            bail!("comm adopt before init_snapshot");
        }
        for (leaf, lit) in adopt {
            let r = self.layout.range(*leaf);
            lit.to_slice::<f32>(&mut comm.snap[r])
                .map_err(|e| anyhow::anyhow!("comm adopt: leaf {leaf}: {e}"))?;
        }
        Ok(())
    }

    /// Encode replica `rep`'s contribution to sync `sync_index` over
    /// the due ranges of `frag`. `state` holds the replica's literal
    /// handles in manifest leaf order (the first `n_leaves` are the
    /// parameters). Returns exactly [`SyncEncoder::payload_bytes`] bytes.
    pub fn encode_replica(
        &self,
        rep: usize,
        state: &[Arc<xla::Literal>],
        comm: &mut CommState,
        frag: Option<usize>,
        sync_index: u64,
    ) -> Result<Vec<u8>> {
        let total = self.layout.total();
        if state.len() < self.layout.n_leaves() {
            bail!(
                "comm encode: replica {rep} has {} state leaves, layout wants {}",
                state.len(),
                self.layout.n_leaves()
            );
        }
        if comm.scratch.len() != total {
            comm.scratch = vec![0.0; total];
        }
        // pull the due leaves into the scratch arena
        for leaf in self.layout.leaves(self.fragments, frag) {
            let r = self.layout.range(leaf);
            state[leaf]
                .to_slice::<f32>(&mut comm.scratch[r])
                .map_err(|e| anyhow::anyhow!("comm encode: pulling leaf {leaf}: {e}"))?;
        }
        let ranges = self.ranges(frag);
        let mut out = Vec::with_capacity(self.payload_bytes(frag));
        if self.codec.is_identity() {
            // legacy wire: raw f32 parameters, bit for bit
            for r in &ranges {
                let seed = self.seed_for(sync_index, rep, r.start);
                self.codec.encode(&comm.scratch[r.clone()], seed, &mut out);
            }
            return Ok(out);
        }
        if comm.snap.len() != total {
            bail!("comm encode: lossy codec without init_snapshot (replica {rep})");
        }
        for r in &ranges {
            // x = (global - theta) + residual, the error-compensated delta
            for i in r.clone() {
                comm.staging[i] = (comm.snap[i] - comm.scratch[i]) + comm.residual[i];
            }
            let seed = self.seed_for(sync_index, rep, r.start);
            let before = out.len();
            self.codec.encode(&comm.staging[r.clone()], seed, &mut out);
            // residual <- x - dq(x): decode our own bytes (scratch is
            // free again — theta was consumed forming x)
            self.codec
                .decode(&out[before..], &mut comm.scratch[r.clone()])?;
            for i in r.clone() {
                comm.residual[i] = comm.staging[i] - comm.scratch[i];
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::{codec_for, OuterBits};
    use crate::runtime::HostTensor;

    fn layout() -> Arc<FlatLayout> {
        Arc::new(FlatLayout::new(vec![vec![3], vec![2, 2], vec![5]]))
    }

    fn lits(layout: &FlatLayout, fill: impl Fn(usize) -> f32) -> Vec<Arc<xla::Literal>> {
        (0..layout.n_leaves())
            .map(|l| {
                let r = layout.range(l);
                let v: Vec<f32> = r.map(|i| fill(i)).collect();
                Arc::new(
                    HostTensor::from_vec(layout.shape(l), v)
                        .to_literal()
                        .unwrap(),
                )
            })
            .collect()
    }

    #[test]
    fn identity_payload_is_raw_params() {
        let l = layout();
        let enc = SyncEncoder::new(Arc::clone(&l), codec_for(OuterBits::Fp32), 1, 7);
        let state = lits(&l, |i| i as f32 * 0.5 - 2.0);
        let mut comm = CommState::default();
        let bytes = enc
            .encode_replica(0, &state, &mut comm, None, 0)
            .unwrap();
        assert_eq!(bytes.len(), enc.payload_bytes(None));
        assert_eq!(bytes.len(), l.total() * 4);
        let got: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let want: Vec<f32> = (0..l.total()).map(|i| i as f32 * 0.5 - 2.0).collect();
        assert_eq!(got, want);
        assert!(comm.residual().is_empty(), "identity never builds residuals");
    }

    #[test]
    fn lossy_requires_snapshot_and_builds_residual() {
        let l = layout();
        let enc = SyncEncoder::new(Arc::clone(&l), codec_for(OuterBits::Int4), 1, 7);
        let state = lits(&l, |i| (i as f32).sin());
        let mut comm = CommState::default();
        assert!(
            enc.encode_replica(0, &state, &mut comm, None, 0).is_err(),
            "lossy encode without snapshot must fail loudly"
        );
        enc.init_snapshot(&mut comm, &lits(&l, |_| 0.0)).unwrap();
        let bytes = enc.encode_replica(0, &state, &mut comm, None, 0).unwrap();
        assert_eq!(bytes.len(), enc.payload_bytes(None));
        // residual = x - dq is bounded by one quantization step
        let maxabs = (0..l.total())
            .map(|i| (i as f32).sin().abs())
            .fold(0.0f32, f32::max);
        assert!(comm
            .residual()
            .iter()
            .all(|&r| r.abs() <= maxabs / 7.0 * 1.0001));
    }

    #[test]
    fn payload_bytes_match_fragment_ranges() {
        let l = layout();
        for bits in OuterBits::ALL {
            let enc = SyncEncoder::new(Arc::clone(&l), codec_for(bits), 2, 0);
            let full = enc.payload_bytes(None);
            let f0 = enc.payload_bytes(Some(0));
            let f1 = enc.payload_bytes(Some(1));
            assert!(f0 > 0 && f1 > 0, "{bits:?}");
            assert!(f0 < full && f1 < full, "{bits:?}");
        }
    }

    #[test]
    fn adopt_refreshes_only_listed_leaves() {
        let l = layout();
        let enc = SyncEncoder::new(Arc::clone(&l), codec_for(OuterBits::Int8), 1, 1);
        let mut comm = CommState::default();
        enc.init_snapshot(&mut comm, &lits(&l, |_| 1.0)).unwrap();
        let fresh = lits(&l, |_| 9.0);
        enc.adopt(&mut comm, &[(1, Arc::clone(&fresh[1]))]).unwrap();
        let r1 = l.range(1);
        for i in 0..l.total() {
            let want = if r1.contains(&i) { 9.0 } else { 1.0 };
            assert_eq!(comm.snap[i], want, "element {i}");
        }
    }

    #[test]
    fn seeds_vary_by_sync_replica_and_offset() {
        let l = layout();
        let enc = SyncEncoder::new(Arc::clone(&l), codec_for(OuterBits::Int4), 1, 9);
        let base = enc.seed_for(0, 0, 0);
        assert_ne!(base, enc.seed_for(1, 0, 0));
        assert_ne!(base, enc.seed_for(0, 1, 0));
        assert_ne!(base, enc.seed_for(0, 0, 8));
    }
}
