//! Worker-side comm state and the per-run [`CommLink`]: where both
//! legs of the comm plane touch replica state.
//!
//! # Arena ownership (the memory model)
//!
//! Comm-side memory is split by what is *genuinely* per-replica:
//!
//! - [`WorkerComm`] — **one per pool worker**, shared by every replica
//!   the worker owns: the `snap` arena (the replicas' view of the
//!   global as of the last broadcast — byte-identical across replicas,
//!   because the broadcast is one stream) plus the transient `staging`
//!   and `scratch` arenas (dead between calls). Sharing these cuts
//!   lossy-run comm memory from 4 arenas per replica to 3 per worker +
//!   1 per replica — ~3x at M=8 with the inline (one-worker) driver.
//! - [`ReplicaComm`] — **one per replica**: only the up-wire
//!   error-feedback residual, the single piece of comm state whose
//!   value actually differs between replicas.
//!
//! Identity/identity runs (and Data-Parallel) allocate none of this:
//! they keep the zero-copy `Arc` literal handoff.
//!
//! # Per sync event, for the due fragment's ranges
//!
//! **Up** ([`CommLink::encode_replica`], on the replica's worker):
//! pull theta into `scratch`; identity codecs ship the raw f32
//! parameters (the legacy wire, bit for bit); lossy codecs ship the
//! error-compensated outer delta `x = (snap - theta) + residual` and
//! carry `x - dq(x)` in the replica's residual.
//!
//! **Down** ([`CommLink::adopt_encoded`], on every worker): decode the
//! coordinator's single broadcast payload, advance `snap += dq`, and
//! rebuild the synced leaves' literals from the snap — once per
//! worker, shared by all its replicas (the per-worker analogue of the
//! coordinator's deduplicated upload). Identity down-wires instead
//! refresh the snap straight from the broadcast literals
//! ([`CommLink::adopt_literals`]) — no bytes, no decode.
//!
//! # Determinism rules
//!
//! Payload bytes are a pure function of (codec, run seed, direction,
//! sync index, stream, range offsets, values). The shared `snap`
//! advances only at broadcast boundaries, identically on every worker
//! (same bytes, same decode, same f32 adds), and each replica's
//! residual advances only with its own sync sequence on its owner
//! worker. This is what lets `tests/comm_codec.rs` pin workers 1 vs 4
//! bit-identical at every (up, down) width pair.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::HostTensor;
use crate::transport::frame::{WireBuf, WireSlice};

use super::channel::Channel;

/// Per-worker shared comm arenas (see the module docs for why these
/// are per worker, not per replica).
#[derive(Default)]
pub struct WorkerComm {
    /// The replicas' view of the global as of the last broadcast.
    snap: Vec<f32>,
    /// Delta / decode staging (transient).
    staging: Vec<f32>,
    /// Device→host pull and dq arena (transient).
    scratch: Vec<f32>,
    /// Recycled up-wire payload buffers: spent payloads the driver
    /// routes back after the reduce, reused by this worker's next
    /// encodes so steady-state syncs allocate no fresh wire buffers.
    /// Each carries the transport's reserved frame prefix, so encoding
    /// into one produces a ship-ready frame with no assembly copy.
    spares: Vec<WireBuf>,
}

impl WorkerComm {
    /// The snapshot arena (empty until [`CommLink::init_snapshot`]) —
    /// exposed for tests.
    pub fn snap(&self) -> &[f32] {
        &self.snap
    }

    /// Return a spent wire payload buffer for reuse by this worker's
    /// next encode. Capacity is retained; every byte is rewritten on
    /// reuse.
    pub fn recycle(&mut self, mut buf: WireBuf) {
        if self.spares.len() < 16 {
            buf.reset();
            self.spares.push(buf);
        }
    }

    /// Pop a recycled payload buffer (or a fresh — audited — one).
    fn take_buf(&mut self) -> WireBuf {
        self.spares.pop().unwrap_or_default()
    }

    /// Comm arena footprint in bytes — the counter behind
    /// `DriveOutcome::comm_arena_bytes`, so the per-worker sharing
    /// can't silently regress to per-replica.
    pub fn arena_bytes(&self) -> u64 {
        4 * (self.snap.len() + self.staging.len() + self.scratch.len()) as u64
    }
}

/// Per-replica comm state: only the up-wire error-feedback residual.
#[derive(Default)]
pub struct ReplicaComm {
    residual: Vec<f32>,
}

impl ReplicaComm {
    /// Restore a replica's residual from a checkpoint — the EF stream
    /// continues bit-identically because encode seeds are pure in the
    /// sync index and replica id, neither of which shifts on resume.
    pub fn restore(residual: Vec<f32>) -> ReplicaComm {
        ReplicaComm { residual }
    }

    /// Hand the residual back for checkpointing.
    pub fn into_residual(self) -> Vec<f32> {
        self.residual
    }

    /// The error-feedback residual (empty until the link initializes
    /// it for a lossy up-wire) — exposed for tests.
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Comm arena footprint in bytes (see [`WorkerComm::arena_bytes`]).
    pub fn arena_bytes(&self) -> u64 {
        4 * self.residual.len() as u64
    }
}

/// Both legs of one run's comm plane, as workers see them: the up-wire
/// channel the worker encodes replica contributions with, and the
/// down-wire channel it decodes broadcasts with. Cloned into every
/// worker (channels are immutable recipes).
#[derive(Clone)]
pub struct CommLink {
    up: Channel,
    down: Channel,
}

impl CommLink {
    pub fn new(up: Channel, down: Channel) -> CommLink {
        // a mismatched pair would index arenas sized from one layout
        // with ranges from the other — refuse in release builds too
        assert!(
            Arc::ptr_eq(up.layout(), down.layout()),
            "comm link: up and down channels must share one FlatLayout"
        );
        CommLink { up, down }
    }

    /// Build both legs of a run's comm plane from its recipe — the
    /// same construction as `OuterSync::link()`, callable where no
    /// `OuterSync` exists (a remote `diloco worker` rebuilding its
    /// comm state from the handshake config). Bit-compatibility with
    /// the coordinator side needs only equal (layout, codec widths,
    /// fragment count, run seed) — exactly the fields the TCP
    /// handshake pins.
    pub fn for_run(
        layout: &Arc<crate::runtime::FlatLayout>,
        up: super::codec::OuterBits,
        down: super::codec::OuterBits,
        fragments: usize,
        run_seed: u64,
    ) -> CommLink {
        use super::channel::Direction;
        use super::codec::codec_for;
        CommLink::new(
            Channel::new(
                Arc::clone(layout),
                codec_for(up),
                fragments,
                run_seed,
                Direction::Up,
            ),
            Channel::new(
                Arc::clone(layout),
                codec_for(down),
                fragments,
                run_seed,
                Direction::Down,
            ),
        )
    }

    pub fn up(&self) -> &Channel {
        &self.up
    }

    pub fn down(&self) -> &Channel {
        &self.down
    }

    /// Whether the run needs worker-side comm state at all. False for
    /// identity/identity runs, which keep the PR 2 zero-copy literal
    /// handoff end to end.
    pub fn is_active(&self) -> bool {
        !self.up.is_identity() || !self.down.is_identity()
    }

    /// Size the worker's shared arenas and capture the initial global
    /// from the replica's state literals (call once before the first
    /// inner step, when replica state still equals the global init —
    /// Algorithm 1 line 2; any of the worker's replicas works, they
    /// are identical at that point).
    pub fn init_snapshot(
        &self,
        wc: &mut WorkerComm,
        state: &[Arc<xla::Literal>],
    ) -> Result<()> {
        let layout = self.up.layout();
        let total = layout.total();
        wc.snap = vec![0.0; total];
        wc.staging = vec![0.0; total];
        // the pull arena serves only the up-wire encode; identity
        // up-wires never encode through the driver, so don't carry a
        // dead full-model arena per worker (encode_replica sizes it
        // lazily for direct callers)
        if !self.up.is_identity() {
            wc.scratch = vec![0.0; total];
        }
        for leaf in 0..layout.n_leaves() {
            let r = layout.range(leaf);
            state[leaf]
                .to_slice::<f32>(&mut wc.snap[r])
                .map_err(|e| anyhow::anyhow!("comm snapshot: leaf {leaf}: {e}"))?;
        }
        Ok(())
    }

    /// Size one replica's residual (lossy up-wires only; identity
    /// up-wires never form deltas and keep this empty).
    pub fn init_replica(&self, rc: &mut ReplicaComm) {
        if !self.up.is_identity() {
            rc.residual = vec![0.0; self.up.layout().total()];
        }
    }

    /// Resume-path snapshot init: size the worker's shared arenas and
    /// fill `snap` from a raw flat arena instead of replica literals.
    /// Mid-run the replicas' view of the global is NOT the global
    /// itself (lossy down-wires lag it by the EF residual), so a
    /// restored worker must start from the checkpointed broadcast view
    /// — `OuterSync::broadcast_view` — not from replica state.
    pub fn init_snapshot_from(&self, wc: &mut WorkerComm, view: &[f32]) -> Result<()> {
        let total = self.up.layout().total();
        if view.len() != total {
            bail!(
                "comm snapshot restore: got {} elements, layout wants {total}",
                view.len()
            );
        }
        wc.snap = view.to_vec();
        wc.staging = vec![0.0; total];
        if !self.up.is_identity() {
            wc.scratch = vec![0.0; total];
        }
        Ok(())
    }

    /// Build the full-leaf adopt list from the worker's current snap —
    /// how a joiner is initialized when the link is active: the snap IS
    /// the broadcast view every sibling replica holds (down-wire EF
    /// stream state included), so the joiner inherits it exactly and
    /// identically on every worker.
    pub fn snap_literals(&self, wc: &WorkerComm) -> Result<Vec<(usize, Arc<xla::Literal>)>> {
        let layout = self.up.layout();
        if wc.snap.len() != layout.total() {
            bail!("comm snap_literals before init_snapshot");
        }
        let mut adopt = Vec::with_capacity(layout.n_leaves());
        for leaf in 0..layout.n_leaves() {
            let r = layout.range(leaf);
            let lit = HostTensor::from_vec(layout.shape(leaf), wc.snap[r].to_vec())
                .to_literal()
                .map_err(|e| anyhow::anyhow!("comm snap_literals: leaf {leaf}: {e}"))?;
            adopt.push((leaf, Arc::new(lit)));
        }
        Ok(adopt)
    }

    /// Identity-down broadcast: refresh the shared snap from the adopt
    /// list's literals (synced leaves only; untouched leaves keep
    /// their values).
    pub fn adopt_literals(
        &self,
        wc: &mut WorkerComm,
        adopt: &[(usize, Arc<xla::Literal>)],
    ) -> Result<()> {
        if adopt.is_empty() {
            return Ok(());
        }
        let layout = self.up.layout();
        if wc.snap.is_empty() && layout.total() > 0 {
            bail!("comm adopt before init_snapshot");
        }
        for (leaf, lit) in adopt {
            let r = layout.range(*leaf);
            lit.to_slice::<f32>(&mut wc.snap[r])
                .map_err(|e| anyhow::anyhow!("comm adopt: leaf {leaf}: {e}"))?;
        }
        Ok(())
    }

    /// Lossy-down broadcast: decode the coordinator's single encoded
    /// payload, advance `snap += dq` over the due ranges, and build
    /// the refreshed leaves' literals from the snap — returned as the
    /// adopt list every replica this worker owns applies (one decode
    /// and one upload per leaf per *worker*, never per replica).
    pub fn adopt_encoded(
        &self,
        wc: &mut WorkerComm,
        frag: Option<usize>,
        bytes: &[u8],
    ) -> Result<Vec<(usize, Arc<xla::Literal>)>> {
        let layout = self.down.layout();
        if wc.snap.len() != layout.total() {
            bail!("comm adopt_encoded before init_snapshot");
        }
        self.down.decode(bytes, frag, &mut wc.staging)?;
        let ranges = self.down.ranges(frag);
        for r in &ranges {
            for i in r.clone() {
                wc.snap[i] += wc.staging[i];
            }
        }
        let mut adopt = Vec::new();
        for leaf in layout.leaves(self.down.fragments(), frag) {
            let r = layout.range(leaf);
            let lit = HostTensor::from_vec(layout.shape(leaf), wc.snap[r].to_vec())
                .to_literal()
                .map_err(|e| anyhow::anyhow!("comm adopt_encoded: leaf {leaf}: {e}"))?;
            adopt.push((leaf, Arc::new(lit)));
        }
        Ok(adopt)
    }

    /// Up-wire payload size of one replica's contribution to a sync of
    /// `frag` (what every worker puts on the channel).
    pub fn payload_bytes(&self, frag: Option<usize>) -> usize {
        self.up.payload_bytes(frag)
    }

    /// Encode replica `rep`'s contribution to sync `sync_index` over
    /// the due ranges of `frag`. `state` holds the replica's literal
    /// handles in manifest leaf order (the first `n_leaves` are the
    /// parameters). Returns exactly [`CommLink::payload_bytes`] bytes,
    /// as a shareable view of a recycled frame-prefixed buffer — a
    /// transport ships it with zero assembly copies, and the reduce
    /// reclaims the buffer for the next encode.
    pub fn encode_replica(
        &self,
        rep: usize,
        state: &[Arc<xla::Literal>],
        wc: &mut WorkerComm,
        rc: &mut ReplicaComm,
        frag: Option<usize>,
        sync_index: u64,
    ) -> Result<WireSlice> {
        let layout = self.up.layout();
        let total = layout.total();
        if state.len() < layout.n_leaves() {
            bail!(
                "comm encode: replica {rep} has {} state leaves, layout wants {}",
                state.len(),
                layout.n_leaves()
            );
        }
        if wc.scratch.len() != total {
            wc.scratch = vec![0.0; total];
        }
        // pull the due leaves into the shared scratch arena
        for leaf in layout.leaves(self.up.fragments(), frag) {
            let r = layout.range(leaf);
            state[leaf]
                .to_slice::<f32>(&mut wc.scratch[r])
                .map_err(|e| anyhow::anyhow!("comm encode: pulling leaf {leaf}: {e}"))?;
        }
        if self.up.is_identity() {
            // legacy wire: raw f32 parameters, bit for bit
            let mut out = wc.take_buf();
            self.up
                .encode_raw_into(&wc.scratch, frag, sync_index, rep as u64, &mut out);
            return Ok(WireSlice::whole(Arc::new(out)));
        }
        if wc.snap.len() != total {
            bail!("comm encode: lossy up-wire without init_snapshot (replica {rep})");
        }
        if rc.residual.len() != total {
            bail!("comm encode: replica {rep} residual not initialized");
        }
        // x = (global view - theta) + residual, the error-compensated
        // delta; the channel owns the EF arithmetic
        for r in self.up.ranges(frag) {
            for i in r {
                wc.staging[i] = wc.snap[i] - wc.scratch[i];
            }
        }
        // Within one worker the encode stays single-threaded: the
        // parallelism across the worker pool already covers the cores.
        let mut out = wc.take_buf();
        self.up.encode_ef_into(
            &mut wc.staging,
            &mut rc.residual,
            frag,
            sync_index,
            rep as u64,
            1,
            &mut out,
        )?;
        Ok(WireSlice::whole(Arc::new(out)))
    }

    /// How many chunks a streamed up-leg encode of `frag` cuts:
    /// one per [`STREAM_CHUNK_BYTES`] of payload, clamped to
    /// `1..=32`. Chunk count never changes the payload bytes (pinned
    /// by the shard-count-invariance tests), so this is purely a
    /// latency/overhead trade — small payloads go out whole.
    pub fn stream_chunks(&self, frag: Option<usize>) -> usize {
        self.payload_bytes(frag).div_ceil(STREAM_CHUNK_BYTES).clamp(1, 32)
    }

    /// [`CommLink::encode_replica`] with streaming flushes for lossy
    /// up-wires: the contribution is encoded in `chunks` block-aligned
    /// chunks and each is handed to `flush` as `(wire-byte offset,
    /// bytes)` the moment it is ready — contiguous offsets from 0, in
    /// payload order, concatenating to exactly the one-shot payload
    /// ([`Channel::encode_ef_streamed`]). Nothing is returned: the
    /// bytes went out through `flush`, the encode buffer is recycled
    /// into the worker's spare pool, and the report carries
    /// `SyncPayload::Streamed` in place of the payload.
    ///
    /// Identity up-wires never stream (their raw-literal path has no
    /// encode to overlap) — calling this on one is a driver bug and
    /// fails loud. On `Err` from `flush` the replica's EF residual is
    /// poisoned; the run must be abandoned, never the sync retried.
    #[allow(clippy::too_many_arguments)]
    pub fn encode_replica_streamed(
        &self,
        rep: usize,
        state: &[Arc<xla::Literal>],
        wc: &mut WorkerComm,
        rc: &mut ReplicaComm,
        frag: Option<usize>,
        sync_index: u64,
        chunks: usize,
        flush: &mut dyn FnMut(usize, &[u8]) -> Result<()>,
    ) -> Result<()> {
        let layout = self.up.layout();
        let total = layout.total();
        if self.up.is_identity() {
            bail!("comm encode: identity up-wire never streams (replica {rep})");
        }
        if state.len() < layout.n_leaves() {
            bail!(
                "comm encode: replica {rep} has {} state leaves, layout wants {}",
                state.len(),
                layout.n_leaves()
            );
        }
        if wc.scratch.len() != total {
            wc.scratch = vec![0.0; total];
        }
        for leaf in layout.leaves(self.up.fragments(), frag) {
            let r = layout.range(leaf);
            state[leaf]
                .to_slice::<f32>(&mut wc.scratch[r])
                .map_err(|e| anyhow::anyhow!("comm encode: pulling leaf {leaf}: {e}"))?;
        }
        if wc.snap.len() != total {
            bail!("comm encode: lossy up-wire without init_snapshot (replica {rep})");
        }
        if rc.residual.len() != total {
            bail!("comm encode: replica {rep} residual not initialized");
        }
        for r in self.up.ranges(frag) {
            for i in r {
                wc.staging[i] = wc.snap[i] - wc.scratch[i];
            }
        }
        let mut out = wc.take_buf();
        let result = self.up.encode_ef_streamed(
            &mut wc.staging,
            &mut rc.residual,
            frag,
            sync_index,
            rep as u64,
            chunks,
            &mut out,
            flush,
        );
        wc.recycle(out);
        result
    }
}

/// Target payload bytes per streamed up-leg chunk (~64 KiB): big
/// enough that per-chunk frame + syscall overhead is noise, small
/// enough that encode and socket genuinely overlap on real payloads.
pub const STREAM_CHUNK_BYTES: usize = 64 << 10;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::channel::Direction;
    use crate::comm::codec::{codec_for, OuterBits};
    use crate::runtime::FlatLayout;

    fn layout() -> Arc<FlatLayout> {
        Arc::new(FlatLayout::new(vec![vec![3], vec![2, 2], vec![5]]))
    }

    fn link(up: OuterBits, down: OuterBits) -> CommLink {
        let l = layout();
        CommLink::new(
            Channel::new(Arc::clone(&l), codec_for(up), 1, 7, Direction::Up),
            Channel::new(l, codec_for(down), 1, 7, Direction::Down),
        )
    }

    fn lits(layout: &FlatLayout, fill: impl Fn(usize) -> f32) -> Vec<Arc<xla::Literal>> {
        (0..layout.n_leaves())
            .map(|l| {
                let r = layout.range(l);
                let v: Vec<f32> = r.map(&fill).collect();
                Arc::new(
                    HostTensor::from_vec(layout.shape(l), v)
                        .to_literal()
                        .unwrap(),
                )
            })
            .collect()
    }

    #[test]
    fn identity_payload_is_raw_params() {
        let l = layout();
        let lk = link(OuterBits::Fp32, OuterBits::Fp32);
        assert!(!lk.is_active());
        let state = lits(&l, |i| i as f32 * 0.5 - 2.0);
        let mut wc = WorkerComm::default();
        let mut rc = ReplicaComm::default();
        let bytes = lk
            .encode_replica(0, &state, &mut wc, &mut rc, None, 0)
            .unwrap();
        assert_eq!(bytes.len(), lk.payload_bytes(None));
        assert_eq!(bytes.len(), l.total() * 4);
        let got: Vec<f32> = bytes
            .as_slice()
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let want: Vec<f32> = (0..l.total()).map(|i| i as f32 * 0.5 - 2.0).collect();
        assert_eq!(got, want);
        assert!(rc.residual().is_empty(), "identity never builds residuals");
    }

    #[test]
    fn lossy_requires_snapshot_and_builds_residual() {
        let l = layout();
        let lk = link(OuterBits::Int4, OuterBits::Fp32);
        assert!(lk.is_active());
        let state = lits(&l, |i| (i as f32).sin());
        let mut wc = WorkerComm::default();
        let mut rc = ReplicaComm::default();
        lk.init_replica(&mut rc);
        assert!(
            lk.encode_replica(0, &state, &mut wc, &mut rc, None, 0).is_err(),
            "lossy encode without snapshot must fail loudly"
        );
        lk.init_snapshot(&mut wc, &lits(&l, |_| 0.0)).unwrap();
        let bytes = lk
            .encode_replica(0, &state, &mut wc, &mut rc, None, 0)
            .unwrap();
        assert_eq!(bytes.len(), lk.payload_bytes(None));
        // residual = x - dq is bounded by one quantization step
        let maxabs = (0..l.total())
            .map(|i| (i as f32).sin().abs())
            .fold(0.0f32, f32::max);
        assert!(rc
            .residual()
            .iter()
            .all(|&r| r.abs() <= maxabs / 7.0 * 1.0001));
    }

    #[test]
    fn adopt_literals_refreshes_only_listed_leaves() {
        let l = layout();
        let lk = link(OuterBits::Int8, OuterBits::Fp32);
        let mut wc = WorkerComm::default();
        lk.init_snapshot(&mut wc, &lits(&l, |_| 1.0)).unwrap();
        let fresh = lits(&l, |_| 9.0);
        lk.adopt_literals(&mut wc, &[(1, Arc::clone(&fresh[1]))])
            .unwrap();
        let r1 = l.range(1);
        for i in 0..l.total() {
            let want = if r1.contains(&i) { 9.0 } else { 1.0 };
            assert_eq!(wc.snap()[i], want, "element {i}");
        }
    }

    #[test]
    fn adopt_encoded_advances_snap_and_builds_shared_literals() {
        let l = layout();
        let lk = link(OuterBits::Fp32, OuterBits::Int8);
        assert!(lk.is_active(), "lossy down alone activates the link");
        let init: Vec<f32> = vec![0.5; l.total()];
        let mut wc = WorkerComm::default();
        lk.init_snapshot(&mut wc, &lits(&l, |_| 0.5)).unwrap();
        // coordinator side: encode one broadcast moving the global to 2.0
        let global: Vec<f32> = vec![2.0; l.total()];
        let mut dw = crate::comm::channel::DownWire::new(lk.down().clone(), &init);
        let bytes = dw.encode_broadcast(&global, None, 0).unwrap();
        let adopt = lk.adopt_encoded(&mut wc, None, bytes.payload()).unwrap();
        assert_eq!(adopt.len(), l.n_leaves());
        // worker snap must land exactly on the coordinator's view
        for (s, v) in wc.snap().iter().zip(dw.view()) {
            assert_eq!(s.to_bits(), v.to_bits());
        }
        // and the literals hold the snap's values
        for (leaf, lit) in &adopt {
            let v = lit.to_vec::<f32>().unwrap();
            let r = l.range(*leaf);
            for (x, i) in v.iter().zip(r) {
                assert_eq!(x.to_bits(), wc.snap()[i].to_bits());
            }
        }
        // rejects decode before init / wrong sizes
        let mut cold = WorkerComm::default();
        assert!(lk.adopt_encoded(&mut cold, None, bytes.payload()).is_err());
        assert!(lk.adopt_encoded(&mut wc, None, &bytes.payload()[1..]).is_err());
    }

    #[test]
    fn arena_bytes_count_shared_vs_per_replica_split() {
        let l = layout();
        let total = l.total() as u64;
        let lk = link(OuterBits::Int4, OuterBits::Int4);
        let mut wc = WorkerComm::default();
        let mut rc = ReplicaComm::default();
        assert_eq!(wc.arena_bytes() + rc.arena_bytes(), 0);
        lk.init_snapshot(&mut wc, &lits(&l, |_| 0.0)).unwrap();
        lk.init_replica(&mut rc);
        assert_eq!(wc.arena_bytes(), 3 * total * 4, "3 shared arenas per worker");
        assert_eq!(rc.arena_bytes(), total * 4, "1 residual per replica");
        // identity up-wire: no residual and no pull scratch — the
        // worker only ever decodes broadcasts
        let lk2 = link(OuterBits::Fp32, OuterBits::Int4);
        let mut rc2 = ReplicaComm::default();
        lk2.init_replica(&mut rc2);
        assert_eq!(rc2.arena_bytes(), 0);
        let mut wc2 = WorkerComm::default();
        lk2.init_snapshot(&mut wc2, &lits(&l, |_| 0.0)).unwrap();
        assert_eq!(wc2.arena_bytes(), 2 * total * 4);
    }

    #[test]
    fn streamed_replica_encode_matches_one_shot() {
        let l = layout();
        let lk = link(OuterBits::Int4, OuterBits::Fp32);
        let state = lits(&l, |i| (i as f32 * 0.3).sin());
        let mk = || {
            let mut wc = WorkerComm::default();
            let mut rc = ReplicaComm::default();
            lk.init_snapshot(&mut wc, &lits(&l, |_| 0.0)).unwrap();
            lk.init_replica(&mut rc);
            (wc, rc)
        };
        let (mut wc0, mut rc0) = mk();
        let one_shot = lk
            .encode_replica(1, &state, &mut wc0, &mut rc0, None, 5)
            .unwrap();
        for chunks in [1, 3] {
            let (mut wc, mut rc) = mk();
            let mut streamed = Vec::new();
            lk.encode_replica_streamed(1, &state, &mut wc, &mut rc, None, 5, chunks, &mut |off, b| {
                assert_eq!(off, streamed.len(), "chunks={chunks}");
                streamed.extend_from_slice(b);
                Ok(())
            })
            .unwrap();
            assert_eq!(streamed, one_shot.as_slice(), "chunks={chunks}");
            assert_eq!(rc.residual(), rc0.residual());
            // the encode buffer came back to the spare pool
            assert_eq!(wc.spares.len(), 1);
        }
        // identity up-wires must refuse to stream
        let idlk = link(OuterBits::Fp32, OuterBits::Fp32);
        let mut wc = WorkerComm::default();
        let mut rc = ReplicaComm::default();
        assert!(idlk
            .encode_replica_streamed(0, &state, &mut wc, &mut rc, None, 0, 1, &mut |_, _| Ok(()))
            .is_err());
        // chunk-count heuristic: tiny payloads go out whole
        assert_eq!(lk.stream_chunks(None), 1);
    }

    #[test]
    fn recycled_buffers_encode_bit_identically() {
        let l = layout();
        for up in [OuterBits::Fp32, OuterBits::Int8] {
            let lk = link(up, OuterBits::Fp32);
            let state = lits(&l, |i| (i as f32 * 0.7).cos());
            let mut wc = WorkerComm::default();
            let mut rc = ReplicaComm::default();
            let mut wc2 = WorkerComm::default();
            let mut rc2 = ReplicaComm::default();
            lk.init_snapshot(&mut wc, &lits(&l, |_| 0.0)).unwrap();
            lk.init_snapshot(&mut wc2, &lits(&l, |_| 0.0)).unwrap();
            lk.init_replica(&mut rc);
            lk.init_replica(&mut rc2);
            // Prime the fresh-allocation reference path.
            let a = lk
                .encode_replica(0, &state, &mut wc2, &mut rc2, None, 3)
                .unwrap();
            // Recycle a dirty, differently-sized buffer into the pool
            // and encode through it: every byte must still be written.
            let arena_before = wc.arena_bytes();
            wc.recycle(WireBuf::from_payload(&vec![0xAAu8; a.len() + 37]));
            assert_eq!(
                wc.arena_bytes(),
                arena_before,
                "spare wire buffers are transient, not arena state"
            );
            let b = lk
                .encode_replica(0, &state, &mut wc, &mut rc, None, 3)
                .unwrap();
            assert_eq!(a, b, "pooled buffer changed the {up:?} wire");
            assert_eq!(rc.residual(), rc2.residual());
            // Returning the spent payload refills the pool for the
            // next sync (the slice is the buffer's only holder here).
            for spent in crate::transport::frame::reclaim_wires(vec![b]) {
                wc.recycle(spent);
            }
            assert_eq!(wc.spares.len(), 1);
        }
    }
}
