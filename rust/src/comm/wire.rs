//! Wire accounting: exact byte counts for everything the outer step
//! moves across the (simulated) cross-datacenter boundary.
//!
//! One [`SyncWireRecord`] lands per outer sync event — full or
//! streaming-fragment — with the encoded payload size per replica
//! (identical across replicas: same codec, same due ranges), the
//! replica count, and the broadcast size. Totals are derived, never
//! stored, so the records are the single source of truth for the
//! sweep store's `wire_up_bytes` / `wire_down_bytes` and the report's
//! loss-delta-vs-wire-bytes table.
//!
//! Directions, from the coordinator's point of view:
//!
//! - **up** — replica → coordinator: the encoded sync contribution,
//!   counted per replica (an all-reduce ingests every replica's
//!   payload, so `bytes_up = replicas * bytes_per_replica`);
//! - **down** — coordinator → replica: the refreshed global fragment,
//!   counted **once** per sync (a bandwidth-optimal broadcast costs
//!   ~one payload regardless of the fan-out, and ours is literally one
//!   stream: deduplicated `Arc` literals at the identity width, or a
//!   single encoded payload every worker decodes) at the down-wire
//!   codec's exact encoded size — `--outer-bits-down` below 32 shrinks
//!   this number by the same ~bits/32 factor as the up-wire's.

/// Exact wire traffic of one outer sync event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SyncWireRecord {
    /// 0-based sync event index within the run.
    pub sync_index: u64,
    /// Streaming fragment id (`None` = full sync / final flush).
    pub frag: Option<usize>,
    /// Replicas that contributed a payload.
    pub replicas: usize,
    /// Encoded bytes received from each replica.
    pub bytes_per_replica: u64,
    /// Broadcast payload pushed back out, once per sync (the down
    /// codec's exact encoded size; `4 * elems` at the identity width).
    pub bytes_down: u64,
}

impl SyncWireRecord {
    /// Total replica→coordinator bytes for this sync.
    pub fn bytes_up(&self) -> u64 {
        self.bytes_per_replica * self.replicas as u64
    }

    /// Up bytes as framed on a real socket: payload plus one
    /// length-prefixed transport header per replica contribution
    /// (`transport::frame::FRAME_OVERHEAD`). The payload counts stay
    /// the paper-facing numbers; framed counts are what the TCP
    /// transport actually moves and what socket calibration compares
    /// against.
    pub fn framed_up(&self) -> u64 {
        self.bytes_up() + self.replicas as u64 * crate::transport::frame::FRAME_OVERHEAD
    }

    /// Down bytes as framed on a real socket: one header for the
    /// single broadcast stream.
    pub fn framed_down(&self) -> u64 {
        self.bytes_down + crate::transport::frame::FRAME_OVERHEAD
    }
}

/// Per-run accumulator, owned by `OuterSync`; one record per sync.
#[derive(Debug, Clone, Default)]
pub struct WireStats {
    records: Vec<SyncWireRecord>,
    /// Transport control traffic (heartbeat frames, handshake frames)
    /// actually moved on sockets. Deliberately **not** part of
    /// [`WireStats::total_framed`]: sync totals are schedule-derived
    /// and transport-invariant (the CI oracle diff depends on that),
    /// while control bytes are a socket fact that varies with wall
    /// clock. Reported on its own line, and not checkpointed — a
    /// resumed run starts a fresh socket session.
    control_bytes: u64,
}

impl WireStats {
    /// Rebuild from checkpointed records. Indices are renumbered to
    /// positional order — `record()` derives them from position, so a
    /// restored accumulator must agree with one that never stopped.
    pub fn from_records(records: Vec<SyncWireRecord>) -> WireStats {
        let records = records
            .into_iter()
            .enumerate()
            .map(|(i, mut r)| {
                r.sync_index = i as u64;
                r
            })
            .collect();
        WireStats {
            records,
            control_bytes: 0,
        }
    }

    /// Fold in transport control traffic (heartbeats, handshakes)
    /// measured by a socket transport.
    pub fn add_control_bytes(&mut self, bytes: u64) {
        self.control_bytes += bytes;
    }

    /// Socket control traffic accumulated this session (0 for
    /// in-process runs).
    pub fn control_bytes(&self) -> u64 {
        self.control_bytes
    }

    pub fn record(
        &mut self,
        frag: Option<usize>,
        replicas: usize,
        bytes_per_replica: u64,
        bytes_down: u64,
    ) {
        let sync_index = self.records.len() as u64;
        self.records.push(SyncWireRecord {
            sync_index,
            frag,
            replicas,
            bytes_per_replica,
            bytes_down,
        });
    }

    /// Per-sync records, in sync order.
    pub fn records(&self) -> &[SyncWireRecord] {
        &self.records
    }

    pub fn syncs(&self) -> u64 {
        self.records.len() as u64
    }

    /// Total replica→coordinator bytes across the run.
    pub fn total_up(&self) -> u64 {
        self.records.iter().map(|r| r.bytes_up()).sum()
    }

    /// Total coordinator→replica broadcast bytes across the run.
    pub fn total_down(&self) -> u64 {
        self.records.iter().map(|r| r.bytes_down).sum()
    }

    pub fn total(&self) -> u64 {
        self.total_up() + self.total_down()
    }

    /// Total up bytes including per-contribution frame headers.
    pub fn total_framed_up(&self) -> u64 {
        self.records.iter().map(|r| r.framed_up()).sum()
    }

    /// Total down bytes including per-broadcast frame headers.
    pub fn total_framed_down(&self) -> u64 {
        self.records.iter().map(|r| r.framed_down()).sum()
    }

    /// Total bytes as framed on a real socket.
    pub fn total_framed(&self) -> u64 {
        self.total_framed_up() + self.total_framed_down()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_totals() {
        let mut w = WireStats::default();
        assert_eq!(w.total(), 0);
        w.record(None, 4, 1000, 500);
        w.record(Some(1), 4, 300, 500);
        assert_eq!(w.syncs(), 2);
        assert_eq!(w.records()[0].sync_index, 0);
        assert_eq!(w.records()[1].sync_index, 1);
        assert_eq!(w.records()[1].frag, Some(1));
        assert_eq!(w.records()[0].bytes_up(), 4000);
        assert_eq!(w.total_up(), 4000 + 1200);
        assert_eq!(w.total_down(), 1000);
        assert_eq!(w.total(), 6200);
    }

    #[test]
    fn framed_totals_add_one_header_per_stream() {
        use crate::transport::frame::FRAME_OVERHEAD;
        let mut w = WireStats::default();
        w.record(None, 4, 1000, 500);
        w.record(Some(1), 4, 300, 500);
        // 4 contributions per sync, 1 broadcast per sync
        assert_eq!(w.records()[0].framed_up(), 4000 + 4 * FRAME_OVERHEAD);
        assert_eq!(w.records()[0].framed_down(), 500 + FRAME_OVERHEAD);
        assert_eq!(w.total_framed_up(), w.total_up() + 8 * FRAME_OVERHEAD);
        assert_eq!(w.total_framed_down(), w.total_down() + 2 * FRAME_OVERHEAD);
        assert_eq!(w.total_framed(), w.total() + 10 * FRAME_OVERHEAD);
    }

    #[test]
    fn control_bytes_stay_out_of_framed_totals() {
        // heartbeat/handshake traffic is a socket fact; the framed
        // totals must stay schedule-derived so the multi-process run's
        // `final:` line diffs clean against the in-process oracle
        let mut w = WireStats::default();
        w.record(None, 2, 100, 50);
        let framed = w.total_framed();
        w.add_control_bytes(36 * 7);
        w.add_control_bytes(36);
        assert_eq!(w.control_bytes(), 36 * 8);
        assert_eq!(w.total_framed(), framed);
        // and a checkpoint restore starts the session counter fresh
        let restored = WireStats::from_records(w.records().to_vec());
        assert_eq!(restored.control_bytes(), 0);
    }
}
