//! Wire codecs: the bit-level encodings a replica's outer-sync
//! contribution takes on the (simulated) cross-datacenter wire.
//!
//! Four widths, matching the paper's section-7 ablation axis:
//!
//! - [`Fp32`] — the identity oracle: raw little-endian f32, the exact
//!   legacy wire format. `decode(encode(x)) == x` bit for bit.
//! - [`Bf16Sim`] — simulated bfloat16: round-to-nearest-even to the
//!   top 16 bits of the f32 pattern (the standard hardware cast), then
//!   widened back on decode. Deterministic, no per-block state.
//! - [`IntQ`] (int8 / int4) — symmetric per-block integer quantization:
//!   each [`BLOCK`]-element block carries one f32 scale
//!   (`max|x| / qmax`) followed by packed signed codes, rounded
//!   *stochastically* so the quantizer is unbiased (`E[decode] = x`).
//!
//! # Determinism
//!
//! Stochastic rounding draws from a [`Rng`] derived **only** from the
//! `seed` argument and the block index — never from global state, time,
//! or call order. Callers derive `seed` from
//! `(run seed, sync index, replica id, range offset)` (see
//! `comm::encoder`), so the same training run produces the same bytes
//! at any worker count and on any schedule. Encoding the same slice
//! with the same seed is always byte-identical.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Elements per quantization block (one f32 scale per block). 256
/// keeps the scale overhead at 0.125 bits/element.
pub const BLOCK: usize = 256;

/// The outer-communication bit width (`--outer-bits` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OuterBits {
    Fp32,
    Bf16,
    Int8,
    Int4,
}

impl OuterBits {
    /// Every width, widest first (sweep + report order).
    pub const ALL: [OuterBits; 4] =
        [OuterBits::Fp32, OuterBits::Bf16, OuterBits::Int8, OuterBits::Int4];

    pub fn parse(s: &str) -> Result<OuterBits> {
        Ok(match s {
            "32" | "fp32" => OuterBits::Fp32,
            "16" | "bf16" => OuterBits::Bf16,
            "8" | "int8" => OuterBits::Int8,
            "4" | "int4" => OuterBits::Int4,
            other => bail!("unknown outer bit width {other:?} (want 32|16|8|4)"),
        })
    }

    /// Nominal payload bits per parameter (excludes per-block scales).
    pub fn bits(self) -> u32 {
        match self {
            OuterBits::Fp32 => 32,
            OuterBits::Bf16 => 16,
            OuterBits::Int8 => 8,
            OuterBits::Int4 => 4,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            OuterBits::Fp32 => "fp32",
            OuterBits::Bf16 => "bf16",
            OuterBits::Int8 => "int8",
            OuterBits::Int4 => "int4",
        }
    }
}

/// A wire codec over contiguous f32 runs (the flat-bus fragment
/// ranges). Implementations are stateless and shared across worker
/// threads and both wire directions; all mutable state (error-feedback
/// residuals, views, arenas) lives with its owner —
/// `comm::encoder::{WorkerComm, ReplicaComm}` worker-side,
/// `comm::channel::DownWire` coordinator-side.
pub trait Codec: Send + Sync {
    fn bits(&self) -> OuterBits;

    /// Identity codecs ship raw f32 replica **parameters** — the exact
    /// legacy wire. Lossy codecs ship error-compensated outer
    /// **deltas** instead (shipping low-bit raw parameters would
    /// destroy the model; deltas are small, centred, and tolerate
    /// 4-bit quantization — Streaming DiLoCo, arXiv:2501.18512).
    fn is_identity(&self) -> bool {
        self.bits() == OuterBits::Fp32
    }

    /// Exact wire size in bytes of a contiguous run of `n` elements
    /// (including per-block scales).
    fn wire_bytes(&self, n: usize) -> usize;

    /// Append the encoding of `src` to `out` — exactly
    /// `wire_bytes(src.len())` bytes, deterministic in `(src, seed)`.
    fn encode(&self, src: &[f32], seed: u64, out: &mut Vec<u8>);

    /// Decode exactly `wire_bytes(dst.len())` bytes into `dst`.
    fn decode(&self, wire: &[u8], dst: &mut [f32]) -> Result<()>;
}

/// The codec for a bit width (one shared instance per run).
pub fn codec_for(bits: OuterBits) -> Arc<dyn Codec> {
    match bits {
        OuterBits::Fp32 => Arc::new(Fp32),
        OuterBits::Bf16 => Arc::new(Bf16Sim),
        OuterBits::Int8 | OuterBits::Int4 => Arc::new(IntQ { bits }),
    }
}

// ---- fp32: the identity oracle ---------------------------------------

pub struct Fp32;

impl Codec for Fp32 {
    fn bits(&self) -> OuterBits {
        OuterBits::Fp32
    }

    fn wire_bytes(&self, n: usize) -> usize {
        4 * n
    }

    fn encode(&self, src: &[f32], _seed: u64, out: &mut Vec<u8>) {
        out.reserve(4 * src.len());
        for &x in src {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn decode(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        if wire.len() != 4 * dst.len() {
            bail!("fp32 decode: {} bytes for {} elements", wire.len(), dst.len());
        }
        for (chunk, d) in wire.chunks_exact(4).zip(dst.iter_mut()) {
            *d = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(())
    }
}

// ---- bf16: simulated bfloat16 cast -----------------------------------

pub struct Bf16Sim;

/// f32 -> bf16 bit pattern with round-to-nearest-even (the hardware
/// cast; finite inputs only, which the bus guarantees).
#[inline]
fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits.wrapping_add(round)) >> 16) as u16
}

#[inline]
fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

impl Codec for Bf16Sim {
    fn bits(&self) -> OuterBits {
        OuterBits::Bf16
    }

    fn wire_bytes(&self, n: usize) -> usize {
        2 * n
    }

    fn encode(&self, src: &[f32], _seed: u64, out: &mut Vec<u8>) {
        out.reserve(2 * src.len());
        for &x in src {
            out.extend_from_slice(&f32_to_bf16(x).to_le_bytes());
        }
    }

    fn decode(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        if wire.len() != 2 * dst.len() {
            bail!("bf16 decode: {} bytes for {} elements", wire.len(), dst.len());
        }
        for (chunk, d) in wire.chunks_exact(2).zip(dst.iter_mut()) {
            *d = bf16_to_f32(u16::from_le_bytes([chunk[0], chunk[1]]));
        }
        Ok(())
    }
}

// ---- int8 / int4: per-block scales + stochastic rounding -------------

pub struct IntQ {
    pub bits: OuterBits,
}

impl IntQ {
    /// Symmetric code range: codes live in [-qmax, qmax].
    fn qmax(&self) -> f32 {
        match self.bits {
            OuterBits::Int8 => 127.0,
            OuterBits::Int4 => 7.0,
            _ => unreachable!("IntQ is only built for int widths"),
        }
    }

    /// Packed code bytes for one block of `n` elements.
    fn code_bytes(&self, n: usize) -> usize {
        match self.bits {
            OuterBits::Int8 => n,
            _ => (n + 1) / 2,
        }
    }
}

impl Codec for IntQ {
    fn bits(&self) -> OuterBits {
        self.bits
    }

    fn wire_bytes(&self, n: usize) -> usize {
        let full = n / BLOCK;
        let tail = n % BLOCK;
        let mut bytes = full * (4 + self.code_bytes(BLOCK));
        if tail > 0 {
            bytes += 4 + self.code_bytes(tail);
        }
        bytes
    }

    fn encode(&self, src: &[f32], seed: u64, out: &mut Vec<u8>) {
        out.reserve(self.wire_bytes(src.len()));
        let qmax = self.qmax();
        let root = Rng::new(seed);
        for (bi, block) in src.chunks(BLOCK).enumerate() {
            let maxabs = block.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            let scale = if maxabs > 0.0 { maxabs / qmax } else { 0.0 };
            out.extend_from_slice(&scale.to_le_bytes());
            if scale == 0.0 {
                // all-zero block: zero codes, no rng draws
                out.extend(std::iter::repeat(0u8).take(self.code_bytes(block.len())));
                continue;
            }
            // per-block child stream: byte output is independent of
            // how the caller splits ranges into blocks upstream
            let mut rng = root.child(bi as u64);
            let mut quantize = |x: f32| -> i32 {
                let y = (x / scale).clamp(-qmax, qmax);
                let f = y.floor();
                let frac = (y - f) as f64;
                // unbiased stochastic rounding: round up w.p. frac
                let up = rng.f64() < frac;
                (f as i32) + if up { 1 } else { 0 }
            };
            match self.bits {
                OuterBits::Int8 => {
                    for &x in block {
                        out.push(quantize(x) as i8 as u8);
                    }
                }
                _ => {
                    // int4: offset-binary nibbles (code + 8 in 1..=15),
                    // two per byte, low nibble first; odd tails pad the
                    // high nibble with 8 (code 0), ignored on decode
                    for pair in block.chunks(2) {
                        let lo = (quantize(pair[0]) + 8) as u8 & 0x0F;
                        let hi = if pair.len() == 2 {
                            (quantize(pair[1]) + 8) as u8 & 0x0F
                        } else {
                            8
                        };
                        out.push(lo | (hi << 4));
                    }
                }
            }
        }
    }

    fn decode(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        if wire.len() != self.wire_bytes(dst.len()) {
            bail!(
                "{} decode: {} bytes for {} elements (expected {})",
                self.bits.label(),
                wire.len(),
                dst.len(),
                self.wire_bytes(dst.len())
            );
        }
        let mut off = 0usize;
        for block in dst.chunks_mut(BLOCK) {
            let scale =
                f32::from_le_bytes([wire[off], wire[off + 1], wire[off + 2], wire[off + 3]]);
            off += 4;
            match self.bits {
                OuterBits::Int8 => {
                    for d in block.iter_mut() {
                        *d = (wire[off] as i8) as f32 * scale;
                        off += 1;
                    }
                }
                _ => {
                    for (i, d) in block.iter_mut().enumerate() {
                        let byte = wire[off + i / 2];
                        let nibble = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                        *d = (nibble as i32 - 8) as f32 * scale;
                    }
                    off += self.code_bytes(block.len());
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 - n as f32 / 2.0) * 0.01).collect()
    }

    #[test]
    fn parse_and_labels() {
        assert_eq!(OuterBits::parse("32").unwrap(), OuterBits::Fp32);
        assert_eq!(OuterBits::parse("bf16").unwrap(), OuterBits::Bf16);
        assert_eq!(OuterBits::parse("8").unwrap(), OuterBits::Int8);
        assert_eq!(OuterBits::parse("int4").unwrap(), OuterBits::Int4);
        assert!(OuterBits::parse("2").is_err());
        for b in OuterBits::ALL {
            assert_eq!(OuterBits::parse(b.label()).unwrap(), b);
            assert_eq!(OuterBits::parse(&b.bits().to_string()).unwrap(), b);
        }
    }

    #[test]
    fn fp32_roundtrip_is_bit_exact() {
        let c = Fp32;
        let xs = vec![0.0f32, -0.0, 1.5e-39, f32::MAX, -3.25, 7e-12];
        let mut wire = Vec::new();
        c.encode(&xs, 9, &mut wire);
        assert_eq!(wire.len(), c.wire_bytes(xs.len()));
        let mut back = vec![0.0f32; xs.len()];
        c.decode(&wire, &mut back).unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bf16_roundtrip_error_bounded() {
        let c = Bf16Sim;
        let xs = ramp(500);
        let mut wire = Vec::new();
        c.encode(&xs, 0, &mut wire);
        assert_eq!(wire.len(), 2 * xs.len());
        let mut back = vec![0.0f32; xs.len()];
        c.decode(&wire, &mut back).unwrap();
        for (&x, &y) in xs.iter().zip(&back) {
            // bf16 has 8 mantissa bits: relative error <= 2^-8
            assert!((x - y).abs() <= x.abs() / 256.0 + 1e-12, "{x} -> {y}");
        }
        // exact on bf16-representable values
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0)), 1.0);
        assert_eq!(bf16_to_f32(f32_to_bf16(-0.5)), -0.5);
    }

    #[test]
    fn int_wire_bytes_formula() {
        let i8c = IntQ { bits: OuterBits::Int8 };
        let i4c = IntQ { bits: OuterBits::Int4 };
        assert_eq!(i8c.wire_bytes(0), 0);
        assert_eq!(i8c.wire_bytes(BLOCK), 4 + BLOCK);
        assert_eq!(i8c.wire_bytes(BLOCK + 10), (4 + BLOCK) + (4 + 10));
        assert_eq!(i4c.wire_bytes(BLOCK), 4 + BLOCK / 2);
        assert_eq!(i4c.wire_bytes(7), 4 + 4); // odd tail packs up
    }

    #[test]
    fn int_roundtrip_error_within_one_scale_step() {
        for bits in [OuterBits::Int8, OuterBits::Int4] {
            let c = IntQ { bits };
            let xs = ramp(BLOCK * 2 + 37); // multi-block + ragged tail
            let mut wire = Vec::new();
            c.encode(&xs, 0xABCD, &mut wire);
            assert_eq!(wire.len(), c.wire_bytes(xs.len()));
            let mut back = vec![0.0f32; xs.len()];
            c.decode(&wire, &mut back).unwrap();
            for (bi, block) in xs.chunks(BLOCK).enumerate() {
                let maxabs = block.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                let scale = maxabs / c.qmax();
                for (i, &x) in block.iter().enumerate() {
                    let y = back[bi * BLOCK + i];
                    assert!(
                        (x - y).abs() <= scale * 1.0001,
                        "{:?} block {bi}[{i}]: {x} -> {y} (scale {scale})",
                        bits
                    );
                }
            }
        }
    }

    #[test]
    fn int_zero_block_and_sign_symmetry() {
        let c = IntQ { bits: OuterBits::Int4 };
        let xs = vec![0.0f32; 10];
        let mut wire = Vec::new();
        c.encode(&xs, 3, &mut wire);
        let mut back = vec![1.0f32; 10];
        c.decode(&wire, &mut back).unwrap();
        assert!(back.iter().all(|&x| x == 0.0));
        // extremes map exactly (frac = 0 at +-qmax)
        let xs = vec![-7.0f32, 7.0, 0.0, 3.5];
        let mut wire = Vec::new();
        c.encode(&xs, 3, &mut wire);
        let mut back = vec![0.0f32; 4];
        c.decode(&wire, &mut back).unwrap();
        assert_eq!(back[0], -7.0);
        assert_eq!(back[1], 7.0);
        assert_eq!(back[2], 0.0);
    }

    #[test]
    fn stochastic_rounding_deterministic_in_seed() {
        let c = IntQ { bits: OuterBits::Int4 };
        let xs: Vec<f32> = (0..BLOCK + 9).map(|i| ((i * 37 % 100) as f32 - 50.0) * 0.013).collect();
        let enc = |seed: u64| {
            let mut w = Vec::new();
            c.encode(&xs, seed, &mut w);
            w
        };
        assert_eq!(enc(42), enc(42), "same seed must be byte-identical");
        assert_ne!(enc(42), enc(43), "distinct seeds must perturb rounding");
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        // mean of many independently-seeded quantizations approaches x
        let c = IntQ { bits: OuterBits::Int4 };
        let xs = vec![0.33f32, -1.27, 2.5, 0.0101, -3.3];
        let n = 4000usize;
        let mut mean = vec![0.0f64; xs.len()];
        let mut back = vec![0.0f32; xs.len()];
        for s in 0..n {
            let mut w = Vec::new();
            c.encode(&xs, s as u64, &mut w);
            c.decode(&w, &mut back).unwrap();
            for (m, &y) in mean.iter_mut().zip(&back) {
                *m += y as f64 / n as f64;
            }
        }
        let scale = 3.3 / 7.0;
        for (&x, &m) in xs.iter().zip(&mean) {
            assert!(
                (x as f64 - m).abs() < 3.0 * scale as f64 / (n as f64).sqrt(),
                "E[q({x})] = {m}"
            );
        }
    }

    #[test]
    fn decode_rejects_wrong_length() {
        for bits in OuterBits::ALL {
            let c = codec_for(bits);
            let mut wire = Vec::new();
            c.encode(&[1.0, 2.0, 3.0], 0, &mut wire);
            let mut dst = vec![0.0f32; 4]; // one element too many
            assert!(c.decode(&wire, &mut dst).is_err(), "{bits:?}");
        }
    }
}
