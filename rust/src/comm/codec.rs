//! Wire codecs: the bit-level encodings a replica's outer-sync
//! contribution takes on the (simulated) cross-datacenter wire.
//!
//! Four widths, matching the paper's section-7 ablation axis:
//!
//! - [`Fp32`] — the identity oracle: raw little-endian f32, the exact
//!   legacy wire format. `decode(encode(x)) == x` bit for bit.
//! - [`Bf16Sim`] — simulated bfloat16: round-to-nearest-even to the
//!   top 16 bits of the f32 pattern (the standard hardware cast), then
//!   widened back on decode. Deterministic, no per-block state.
//! - [`IntQ`] (int8 / int4) — symmetric per-block integer quantization:
//!   each [`BLOCK`]-element block carries one f32 scale
//!   (`max|x| / qmax`) followed by packed signed codes, rounded
//!   *stochastically* so the quantizer is unbiased (`E[decode] = x`).
//!
//! # Kernel shape
//!
//! All codecs run as fixed-width block kernels: the int8/int4 dispatch
//! is hoisted out of the element loop, the stochastic-rounding draws
//! are batched per block (one pass fills the draw buffer, a second
//! branch-free pass quantizes), and scale search / pack / unpack are
//! slice-at-a-time passes over `zip`ped exact chunks that the
//! autovectorizer handles. [`Codec::encode_at`] writes into a
//! caller-sized buffer at an explicit absolute block offset, so a
//! range can be encoded whole or in block-aligned pieces (in
//! parallel) with byte-identical output; [`Codec::decode_add`] fuses
//! dequantize with `+=` accumulation so the coordinator's reduce
//! never materializes a per-replica f32 scratch buffer.
//!
//! # Determinism
//!
//! Stochastic rounding draws from a [`Rng`] derived **only** from the
//! `seed` argument and the absolute block index — never from global
//! state, time, or call order. Callers derive `seed` from
//! `(run seed, sync index, replica id, range offset)` (see
//! `comm::encoder`), so the same training run produces the same bytes
//! at any worker count and on any schedule. Encoding the same slice
//! with the same seed is always byte-identical.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Elements per quantization block (one f32 scale per block). 256
/// keeps the scale overhead at 0.125 bits/element.
pub const BLOCK: usize = 256;

/// The outer-communication bit width (`--outer-bits` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OuterBits {
    Fp32,
    Bf16,
    Int8,
    Int4,
}

impl OuterBits {
    /// Every width, widest first (sweep + report order).
    pub const ALL: [OuterBits; 4] =
        [OuterBits::Fp32, OuterBits::Bf16, OuterBits::Int8, OuterBits::Int4];

    pub fn parse(s: &str) -> Result<OuterBits> {
        Ok(match s {
            "32" | "fp32" => OuterBits::Fp32,
            "16" | "bf16" => OuterBits::Bf16,
            "8" | "int8" => OuterBits::Int8,
            "4" | "int4" => OuterBits::Int4,
            other => bail!("unknown outer bit width {other:?} (want 32|16|8|4)"),
        })
    }

    /// Nominal payload bits per parameter (excludes per-block scales).
    pub fn bits(self) -> u32 {
        match self {
            OuterBits::Fp32 => 32,
            OuterBits::Bf16 => 16,
            OuterBits::Int8 => 8,
            OuterBits::Int4 => 4,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            OuterBits::Fp32 => "fp32",
            OuterBits::Bf16 => "bf16",
            OuterBits::Int8 => "int8",
            OuterBits::Int4 => "int4",
        }
    }
}

/// A wire codec over contiguous f32 runs (the flat-bus fragment
/// ranges). Implementations are stateless and shared across worker
/// threads and both wire directions; all mutable state (error-feedback
/// residuals, views, arenas) lives with its owner —
/// `comm::encoder::{WorkerComm, ReplicaComm}` worker-side,
/// `comm::channel::DownWire` coordinator-side.
pub trait Codec: Send + Sync {
    fn bits(&self) -> OuterBits;

    /// Identity codecs ship raw f32 replica **parameters** — the exact
    /// legacy wire. Lossy codecs ship error-compensated outer
    /// **deltas** instead (shipping low-bit raw parameters would
    /// destroy the model; deltas are small, centred, and tolerate
    /// 4-bit quantization — Streaming DiLoCo, arXiv:2501.18512).
    fn is_identity(&self) -> bool {
        self.bits() == OuterBits::Fp32
    }

    /// Exact wire size in bytes of a contiguous run of `n` elements
    /// (including per-block scales).
    fn wire_bytes(&self, n: usize) -> usize;

    /// Encode `src` into `out`, which must be exactly
    /// `wire_bytes(src.len())` bytes; every byte is written (buffers
    /// may be recycled dirty). `block_off` is the absolute
    /// quantization-block index of `src[0]` within its wire stream:
    /// stochastic-rounding children are drawn per absolute block, so
    /// a range encoded whole or in block-aligned pieces (possibly on
    /// different threads) is byte-identical. Codecs without RNG
    /// ignore `seed` and `block_off`.
    fn encode_at(&self, src: &[f32], seed: u64, block_off: u64, out: &mut [u8]);

    /// Append the encoding of `src` to `out` — exactly
    /// `wire_bytes(src.len())` bytes, deterministic in `(src, seed)`.
    fn encode(&self, src: &[f32], seed: u64, out: &mut Vec<u8>) {
        let start = out.len();
        out.resize(start + self.wire_bytes(src.len()), 0);
        self.encode_at(src, seed, 0, &mut out[start..]);
    }

    /// Decode exactly `wire_bytes(dst.len())` bytes into `dst`.
    fn decode(&self, wire: &[u8], dst: &mut [f32]) -> Result<()>;

    /// Decode exactly `wire_bytes(dst.len())` bytes and **accumulate**
    /// into `dst` (`dst[i] += dq[i]`): the fused decode→reduce
    /// kernel. Bit-identical to decoding into a scratch buffer and
    /// adding element-wise, without the scratch.
    fn decode_add(&self, wire: &[u8], dst: &mut [f32]) -> Result<()>;
}

/// The codec for a bit width (one shared instance per run).
pub fn codec_for(bits: OuterBits) -> Arc<dyn Codec> {
    match bits {
        OuterBits::Fp32 => Arc::new(Fp32),
        OuterBits::Bf16 => Arc::new(Bf16Sim),
        OuterBits::Int8 | OuterBits::Int4 => Arc::new(IntQ { bits }),
    }
}

/// Monomorphized store: `ADD = false` overwrites, `ADD = true`
/// accumulates. Inlined into the block kernels so neither variant
/// carries a per-element branch.
#[inline(always)]
fn store<const ADD: bool>(d: &mut f32, v: f32) {
    if ADD {
        *d += v;
    } else {
        *d = v;
    }
}

// ---- fp32: the identity oracle ---------------------------------------

pub struct Fp32;

impl Fp32 {
    fn decode_impl<const ADD: bool>(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        if wire.len() != 4 * dst.len() {
            bail!("fp32 decode: {} bytes for {} elements", wire.len(), dst.len());
        }
        for (chunk, d) in wire.chunks_exact(4).zip(dst.iter_mut()) {
            store::<ADD>(d, f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(())
    }
}

impl Codec for Fp32 {
    fn bits(&self) -> OuterBits {
        OuterBits::Fp32
    }

    fn wire_bytes(&self, n: usize) -> usize {
        4 * n
    }

    fn encode_at(&self, src: &[f32], _seed: u64, _block_off: u64, out: &mut [u8]) {
        debug_assert_eq!(out.len(), 4 * src.len());
        for (chunk, &x) in out.chunks_exact_mut(4).zip(src) {
            chunk.copy_from_slice(&x.to_le_bytes());
        }
    }

    fn decode(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        self.decode_impl::<false>(wire, dst)
    }

    fn decode_add(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        self.decode_impl::<true>(wire, dst)
    }
}

// ---- bf16: simulated bfloat16 cast -----------------------------------

pub struct Bf16Sim;

/// f32 -> bf16 bit pattern with round-to-nearest-even (the hardware
/// cast; finite inputs only, which the bus guarantees).
#[inline]
fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits.wrapping_add(round)) >> 16) as u16
}

#[inline]
fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

impl Bf16Sim {
    fn decode_impl<const ADD: bool>(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        if wire.len() != 2 * dst.len() {
            bail!("bf16 decode: {} bytes for {} elements", wire.len(), dst.len());
        }
        for (chunk, d) in wire.chunks_exact(2).zip(dst.iter_mut()) {
            store::<ADD>(d, bf16_to_f32(u16::from_le_bytes([chunk[0], chunk[1]])));
        }
        Ok(())
    }
}

impl Codec for Bf16Sim {
    fn bits(&self) -> OuterBits {
        OuterBits::Bf16
    }

    fn wire_bytes(&self, n: usize) -> usize {
        2 * n
    }

    fn encode_at(&self, src: &[f32], _seed: u64, _block_off: u64, out: &mut [u8]) {
        debug_assert_eq!(out.len(), 2 * src.len());
        for (chunk, &x) in out.chunks_exact_mut(2).zip(src) {
            chunk.copy_from_slice(&f32_to_bf16(x).to_le_bytes());
        }
    }

    fn decode(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        self.decode_impl::<false>(wire, dst)
    }

    fn decode_add(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        self.decode_impl::<true>(wire, dst)
    }
}

// ---- int8 / int4: per-block scales + stochastic rounding -------------

pub struct IntQ {
    pub bits: OuterBits,
}

/// One stochastic rounding: `draw` is this element's pre-batched
/// uniform. Division by `scale` (not reciprocal multiply), `clamp`,
/// and `floor` reproduce the retired scalar quantizer bit for bit.
#[inline(always)]
fn quantize_one(x: f32, scale: f32, qmax: f32, draw: f64) -> i32 {
    let y = (x / scale).clamp(-qmax, qmax);
    let f = y.floor();
    // unbiased stochastic rounding: round up w.p. frac
    (f as i32) + (draw < (y - f) as f64) as i32
}

#[inline]
fn encode_block_i8(block: &[f32], draws: &[f64], scale: f32, qmax: f32, codes: &mut [u8]) {
    for ((o, &x), &d) in codes.iter_mut().zip(block).zip(draws) {
        *o = quantize_one(x, scale, qmax, d) as i8 as u8;
    }
}

/// int4: offset-binary nibbles (code + 8 in 1..=15), two per byte, low
/// nibble first; odd tails pad the high nibble with 8 (code 0),
/// ignored on decode.
#[inline]
fn encode_block_i4(block: &[f32], draws: &[f64], scale: f32, qmax: f32, codes: &mut [u8]) {
    let n2 = block.len() / 2;
    for ((o, p), d) in codes[..n2].iter_mut().zip(block.chunks_exact(2)).zip(draws.chunks_exact(2))
    {
        let lo = (quantize_one(p[0], scale, qmax, d[0]) + 8) as u8 & 0x0F;
        let hi = (quantize_one(p[1], scale, qmax, d[1]) + 8) as u8 & 0x0F;
        *o = lo | (hi << 4);
    }
    if block.len() % 2 == 1 {
        let lo = (quantize_one(block[2 * n2], scale, qmax, draws[2 * n2]) + 8) as u8 & 0x0F;
        codes[n2] = lo | 0x80;
    }
}

#[inline]
fn decode_block_i8<const ADD: bool>(codes: &[u8], scale: f32, block: &mut [f32]) {
    for (d, &c) in block.iter_mut().zip(codes) {
        store::<ADD>(d, (c as i8) as f32 * scale);
    }
}

#[inline]
fn decode_block_i4<const ADD: bool>(codes: &[u8], scale: f32, block: &mut [f32]) {
    let n2 = block.len() / 2;
    let (pairs, tail) = block.split_at_mut(n2 * 2);
    for (pair, &byte) in pairs.chunks_exact_mut(2).zip(&codes[..n2]) {
        store::<ADD>(&mut pair[0], ((byte & 0x0F) as i32 - 8) as f32 * scale);
        store::<ADD>(&mut pair[1], ((byte >> 4) as i32 - 8) as f32 * scale);
    }
    if let Some(d) = tail.first_mut() {
        store::<ADD>(d, ((codes[n2] & 0x0F) as i32 - 8) as f32 * scale);
    }
}

impl IntQ {
    /// Symmetric code range: codes live in [-qmax, qmax].
    fn qmax(&self) -> f32 {
        match self.bits {
            OuterBits::Int8 => 127.0,
            OuterBits::Int4 => 7.0,
            _ => unreachable!("IntQ is only built for int widths"),
        }
    }

    /// Packed code bytes for one block of `n` elements.
    fn code_bytes(&self, n: usize) -> usize {
        match self.bits {
            OuterBits::Int8 => n,
            _ => (n + 1) / 2,
        }
    }

    fn decode_impl<const ADD: bool>(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        if wire.len() != self.wire_bytes(dst.len()) {
            bail!(
                "{} decode: {} bytes for {} elements (expected {})",
                self.bits.label(),
                wire.len(),
                dst.len(),
                self.wire_bytes(dst.len())
            );
        }
        let int8 = self.bits == OuterBits::Int8;
        let mut off = 0usize;
        for block in dst.chunks_mut(BLOCK) {
            let cb = self.code_bytes(block.len());
            let scale =
                f32::from_le_bytes([wire[off], wire[off + 1], wire[off + 2], wire[off + 3]]);
            let codes = &wire[off + 4..off + 4 + cb];
            off += 4 + cb;
            if int8 {
                decode_block_i8::<ADD>(codes, scale, block);
            } else {
                decode_block_i4::<ADD>(codes, scale, block);
            }
        }
        Ok(())
    }
}

impl Codec for IntQ {
    fn bits(&self) -> OuterBits {
        self.bits
    }

    fn wire_bytes(&self, n: usize) -> usize {
        let full = n / BLOCK;
        let tail = n % BLOCK;
        let mut bytes = full * (4 + self.code_bytes(BLOCK));
        if tail > 0 {
            bytes += 4 + self.code_bytes(tail);
        }
        bytes
    }

    fn encode_at(&self, src: &[f32], seed: u64, block_off: u64, out: &mut [u8]) {
        debug_assert_eq!(out.len(), self.wire_bytes(src.len()));
        let qmax = self.qmax();
        let int8 = self.bits == OuterBits::Int8;
        let root = Rng::new(seed);
        let mut draws = [0.0f64; BLOCK];
        let mut o = 0usize;
        for (bi, block) in src.chunks(BLOCK).enumerate() {
            let cb = self.code_bytes(block.len());
            // slice-at-a-time scale search
            let maxabs = block.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            let scale = if maxabs > 0.0 { maxabs / qmax } else { 0.0 };
            out[o..o + 4].copy_from_slice(&scale.to_le_bytes());
            let codes = &mut out[o + 4..o + 4 + cb];
            o += 4 + cb;
            if scale == 0.0 {
                // all-zero block: zero codes, no rng draws (explicit
                // writes — the buffer may be recycled dirty)
                codes.fill(0);
                continue;
            }
            // per-absolute-block child stream: byte output is
            // independent of how the caller splits ranges into
            // block-aligned pieces upstream
            let mut rng = root.child(block_off + bi as u64);
            let draws = &mut draws[..block.len()];
            for d in draws.iter_mut() {
                *d = rng.f64();
            }
            if int8 {
                encode_block_i8(block, draws, scale, qmax, codes);
            } else {
                encode_block_i4(block, draws, scale, qmax, codes);
            }
        }
    }

    fn decode(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        self.decode_impl::<false>(wire, dst)
    }

    fn decode_add(&self, wire: &[u8], dst: &mut [f32]) -> Result<()> {
        self.decode_impl::<true>(wire, dst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 - n as f32 / 2.0) * 0.01).collect()
    }

    #[test]
    fn parse_and_labels() {
        assert_eq!(OuterBits::parse("32").unwrap(), OuterBits::Fp32);
        assert_eq!(OuterBits::parse("bf16").unwrap(), OuterBits::Bf16);
        assert_eq!(OuterBits::parse("8").unwrap(), OuterBits::Int8);
        assert_eq!(OuterBits::parse("int4").unwrap(), OuterBits::Int4);
        assert!(OuterBits::parse("2").is_err());
        for b in OuterBits::ALL {
            assert_eq!(OuterBits::parse(b.label()).unwrap(), b);
            assert_eq!(OuterBits::parse(&b.bits().to_string()).unwrap(), b);
        }
    }

    #[test]
    fn fp32_roundtrip_is_bit_exact() {
        let c = Fp32;
        let xs = vec![0.0f32, -0.0, 1.5e-39, f32::MAX, -3.25, 7e-12];
        let mut wire = Vec::new();
        c.encode(&xs, 9, &mut wire);
        assert_eq!(wire.len(), c.wire_bytes(xs.len()));
        let mut back = vec![0.0f32; xs.len()];
        c.decode(&wire, &mut back).unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bf16_roundtrip_error_bounded() {
        let c = Bf16Sim;
        let xs = ramp(500);
        let mut wire = Vec::new();
        c.encode(&xs, 0, &mut wire);
        assert_eq!(wire.len(), 2 * xs.len());
        let mut back = vec![0.0f32; xs.len()];
        c.decode(&wire, &mut back).unwrap();
        for (&x, &y) in xs.iter().zip(&back) {
            // bf16 has 8 mantissa bits: relative error <= 2^-8
            assert!((x - y).abs() <= x.abs() / 256.0 + 1e-12, "{x} -> {y}");
        }
        // exact on bf16-representable values
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0)), 1.0);
        assert_eq!(bf16_to_f32(f32_to_bf16(-0.5)), -0.5);
    }

    #[test]
    fn int_wire_bytes_formula() {
        let i8c = IntQ { bits: OuterBits::Int8 };
        let i4c = IntQ { bits: OuterBits::Int4 };
        assert_eq!(i8c.wire_bytes(0), 0);
        assert_eq!(i8c.wire_bytes(BLOCK), 4 + BLOCK);
        assert_eq!(i8c.wire_bytes(BLOCK + 10), (4 + BLOCK) + (4 + 10));
        assert_eq!(i4c.wire_bytes(BLOCK), 4 + BLOCK / 2);
        assert_eq!(i4c.wire_bytes(7), 4 + 4); // odd tail packs up
    }

    #[test]
    fn int_roundtrip_error_within_one_scale_step() {
        for bits in [OuterBits::Int8, OuterBits::Int4] {
            let c = IntQ { bits };
            let xs = ramp(BLOCK * 2 + 37); // multi-block + ragged tail
            let mut wire = Vec::new();
            c.encode(&xs, 0xABCD, &mut wire);
            assert_eq!(wire.len(), c.wire_bytes(xs.len()));
            let mut back = vec![0.0f32; xs.len()];
            c.decode(&wire, &mut back).unwrap();
            for (bi, block) in xs.chunks(BLOCK).enumerate() {
                let maxabs = block.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                let scale = maxabs / c.qmax();
                for (i, &x) in block.iter().enumerate() {
                    let y = back[bi * BLOCK + i];
                    assert!(
                        (x - y).abs() <= scale * 1.0001,
                        "{:?} block {bi}[{i}]: {x} -> {y} (scale {scale})",
                        bits
                    );
                }
            }
        }
    }

    #[test]
    fn int_zero_block_and_sign_symmetry() {
        let c = IntQ { bits: OuterBits::Int4 };
        let xs = vec![0.0f32; 10];
        let mut wire = Vec::new();
        c.encode(&xs, 3, &mut wire);
        let mut back = vec![1.0f32; 10];
        c.decode(&wire, &mut back).unwrap();
        assert!(back.iter().all(|&x| x == 0.0));
        // extremes map exactly (frac = 0 at +-qmax)
        let xs = vec![-7.0f32, 7.0, 0.0, 3.5];
        let mut wire = Vec::new();
        c.encode(&xs, 3, &mut wire);
        let mut back = vec![0.0f32; 4];
        c.decode(&wire, &mut back).unwrap();
        assert_eq!(back[0], -7.0);
        assert_eq!(back[1], 7.0);
        assert_eq!(back[2], 0.0);
    }

    #[test]
    fn stochastic_rounding_deterministic_in_seed() {
        let c = IntQ { bits: OuterBits::Int4 };
        let xs: Vec<f32> = (0..BLOCK + 9).map(|i| ((i * 37 % 100) as f32 - 50.0) * 0.013).collect();
        let enc = |seed: u64| {
            let mut w = Vec::new();
            c.encode(&xs, seed, &mut w);
            w
        };
        assert_eq!(enc(42), enc(42), "same seed must be byte-identical");
        assert_ne!(enc(42), enc(43), "distinct seeds must perturb rounding");
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        // mean of many independently-seeded quantizations approaches x
        let c = IntQ { bits: OuterBits::Int4 };
        let xs = vec![0.33f32, -1.27, 2.5, 0.0101, -3.3];
        let n = 4000usize;
        let mut mean = vec![0.0f64; xs.len()];
        let mut back = vec![0.0f32; xs.len()];
        for s in 0..n {
            let mut w = Vec::new();
            c.encode(&xs, s as u64, &mut w);
            c.decode(&w, &mut back).unwrap();
            for (m, &y) in mean.iter_mut().zip(&back) {
                *m += y as f64 / n as f64;
            }
        }
        let scale = 3.3 / 7.0;
        for (&x, &m) in xs.iter().zip(&mean) {
            assert!(
                (x as f64 - m).abs() < 3.0 * scale as f64 / (n as f64).sqrt(),
                "E[q({x})] = {m}"
            );
        }
    }

    #[test]
    fn decode_rejects_wrong_length() {
        for bits in OuterBits::ALL {
            let c = codec_for(bits);
            let mut wire = Vec::new();
            c.encode(&[1.0, 2.0, 3.0], 0, &mut wire);
            let mut dst = vec![0.0f32; 4]; // one element too many
            assert!(c.decode(&wire, &mut dst).is_err(), "{bits:?}");
        }
    }

    #[test]
    fn encode_at_pieces_compose_byte_identically() {
        // a range encoded whole == encoded in block-aligned pieces
        // with the matching absolute block offsets (the parallel
        // encode contract)
        let n = BLOCK * 3 + 41;
        let xs: Vec<f32> = (0..n).map(|i| ((i * 29 % 211) as f32 - 105.0) * 0.07).collect();
        for bits in OuterBits::ALL {
            let c = codec_for(bits);
            let mut whole = Vec::new();
            c.encode(&xs, 0xFEED, &mut whole);
            let mut pieced = vec![0xAAu8; c.wire_bytes(n)]; // dirty buffer
            for (cut_blocks, piece) in [(0usize, 2usize), (2, 1), (3, 1)] {
                let lo = cut_blocks * BLOCK;
                let hi = (lo + piece * BLOCK).min(n);
                let wlo = c.wire_bytes(lo);
                let whi = c.wire_bytes(hi.min(n));
                c.encode_at(&xs[lo..hi], 0xFEED, cut_blocks as u64, &mut pieced[wlo..whi]);
            }
            assert_eq!(pieced, whole, "{bits:?}");
        }
    }

    #[test]
    fn decode_add_matches_decode_then_add() {
        let n = BLOCK + 123; // odd int4 tail
        let xs: Vec<f32> = (0..n).map(|i| ((i * 31 % 97) as f32 - 48.0) * 0.011).collect();
        for bits in OuterBits::ALL {
            let c = codec_for(bits);
            let mut wire = Vec::new();
            c.encode(&xs, 7, &mut wire);
            let base: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5 - 3.0).collect();
            let mut scratch = vec![0.0f32; n];
            c.decode(&wire, &mut scratch).unwrap();
            let mut want = base.clone();
            for (w, &s) in want.iter_mut().zip(&scratch) {
                *w += s;
            }
            let mut got = base.clone();
            c.decode_add(&wire, &mut got).unwrap();
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{bits:?}[{i}]");
            }
            // same length validation as decode
            assert!(c.decode_add(&wire, &mut vec![0.0; n + 1]).is_err(), "{bits:?}");
        }
    }
}
