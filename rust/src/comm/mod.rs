//! The bidirectional comm plane: both legs of the H-cadence outer sync
//! as explicit, narrow, exactly-accounted wires (paper section 7;
//! Streaming DiLoCo, arXiv:2501.18512, quantizes *both* the outer
//! gradients and the merged-model broadcast at negligible loss cost;
//! DiLoCoX, arXiv:2506.21263, makes the same bidirectional-compression
//! argument at decentralized scale).
//!
//! # The Channel model
//!
//! A DiLoCo outer sync moves data across the cross-datacenter boundary
//! twice: replica contributions travel **up** to the coordinator, and
//! the refreshed global travels back **down** to every replica. Both
//! legs are instances of one direction-generic [`channel::Channel`] —
//! codec + fragment geometry + seed discipline + error-feedback
//! arithmetic — instantiated twice per run:
//!
//! - **up-wire** (`Direction::Up`, one logical stream per replica):
//!   the identity codec ships raw f32 parameters — byte-for-byte the
//!   legacy wire, so `--outer-bits 32` is bit-identical to the
//!   uncompressed path. Lossy codecs ship the error-compensated outer
//!   delta `x = (snap - theta) + residual`, with the residual owned by
//!   the replica ([`encoder::ReplicaComm`]).
//! - **down-wire** (`Direction::Down`, a single broadcast stream): the
//!   identity codec keeps the zero-copy deduplicated `Arc` literal
//!   handoff — no serialization at all. Lossy codecs
//!   (`--outer-bits-down`) encode each broadcast fragment **once** on
//!   the coordinator as `x = (global - view) + residual`, with the
//!   view and residual owned by the coordinator
//!   ([`channel::DownWire`]); every worker decodes the same payload
//!   into its shared snapshot and rebuilds the synced leaves' literals
//!   for all the replicas it owns ([`encoder::CommLink::adopt_encoded`]).
//!
//! Error feedback makes both legs unbiased over repeated syncs: each
//! quantization error is carried into the next payload, so the
//! time-averaged wire value telescopes to the true value (pinned for
//! both directions by `tests/comm_codec.rs`).
//!
//! # The arena model
//!
//! Comm memory is split by what is genuinely per-replica: the
//! broadcast snapshot and the staging/scratch arenas are **shared per
//! worker** ([`encoder::WorkerComm`] — the snapshot is byte-identical
//! across replicas, staging/scratch are transient), and only the
//! up-wire residual stays per-replica ([`encoder::ReplicaComm`]).
//! At M=8 under the inline driver that is 3 + 8 arenas instead of the
//! old 4-per-replica 32 — the footprint is surfaced as
//! `DriveOutcome::comm_arena_bytes` and pinned by a bytes-allocated
//! test so the sharing can't silently regress.
//!
//! Every byte that crosses the wire is counted in [`wire::WireStats`]
//! — exact encoded sizes per sync, per fragment, per replica, in both
//! directions — and surfaces in `RunMetrics` (`wire_up_bytes` /
//! `wire_down_bytes`), the sweep store, and the `diloco report --exp
//! comm` table. The `netsim` wall-clock model takes the same widths
//! via `WalltimeInput::{outer_bits, outer_bits_down}`.
//!
//! # Determinism rules
//!
//! - Stochastic rounding is seeded purely from `(run seed, direction,
//!   sync index, stream, range offset, block index)` — never from
//!   scheduling, wall-clock, or global state. `stream` is the replica
//!   id on the up-wire and 0 on the down-wire.
//! - The up residual is per-replica state owned by the replica's pool
//!   worker; the down residual and view are coordinator state. Both
//!   advance only with the run's sync sequence.
//! - Reduction happens on the coordinator in replica-index order; the
//!   broadcast is one byte stream decoded identically by every worker.
//!
//! Together these make every (up, down) width pair reproduce
//! bit-identically at any `--workers` count (pinned by
//! `tests/comm_codec.rs`).
//!
//! # Overlap (delayed application)
//!
//! Under `--overlap-tau` the pipeline stretches each sync across two
//! events — payloads encoded at the *send*, the broadcast decoded at
//! the *merge*, τ inner steps later — but both EF streams stay single,
//! ordered sequences: the worker snapshot and the coordinator's
//! down-wire view advance through exactly the same broadcasts in the
//! same order (one in flight at a time, enforced fail-loud), so the
//! telescoping-residual invariants above hold unchanged, and τ=0
//! degenerates to the barrier schedule byte for byte (pinned by
//! `tests/overlap_pipeline.rs` for all 16 width pairs).

pub mod channel;
pub mod codec;
pub mod encoder;
pub mod wire;

pub use channel::{Channel, Direction, DownWire};
pub use codec::{codec_for, Codec, OuterBits};
pub use encoder::{CommLink, ReplicaComm, WorkerComm};
pub use wire::{SyncWireRecord, WireStats};
