//! Compressed outer communication: the wire subsystem for low-bit
//! outer gradients on the flat parameter bus (paper section 7;
//! Streaming DiLoCo, arXiv:2501.18512, shows 4-bit outer gradients
//! cost negligible loss).
//!
//! # The quantize → reduce → dequantize contract
//!
//! Every DiLoCo outer sync moves each replica's contribution across
//! the cross-datacenter boundary. This module makes that wire explicit
//! and cheap to narrow:
//!
//! 1. **quantize** (replica side, [`encoder::SyncEncoder`]): the
//!    replica's due fragment is pulled from its literals and encoded
//!    with the run's [`codec::Codec`]. The identity codec ([`codec::Fp32`])
//!    ships raw f32 parameters — byte-for-byte the legacy wire, so
//!    `--outer-bits 32` is bit-identical to the uncompressed path.
//!    Lossy codecs ship the error-compensated outer delta
//!    `x = (global - theta) + residual` instead, and update the
//!    per-replica error-feedback residual `residual <- x - dq(x)` so
//!    quantization error is carried forward, never lost.
//! 2. **reduce** (coordinator side, `coordinator::sync::OuterSync::sync_encoded`):
//!    payloads are decoded into the reused scratch arena and
//!    accumulated in replica-index order over the precomputed fragment
//!    ranges — identical summation order to the sequential oracle.
//! 3. **dequantize / step**: the accumulated value becomes the outer
//!    gradient (identity: `Delta = global - mean(theta)`; lossy:
//!    `Delta = mean(dq)`) and the Nesterov outer step runs unchanged
//!    on the flat bus. The refreshed fragment is broadcast as
//!    deduplicated f32 literals, and the replica-side snapshot adopts
//!    it so the next delta is formed against the coordinator's exact
//!    global.
//!
//! Every byte that crosses the wire is counted in [`wire::WireStats`]
//! — exact encoded sizes per sync, per fragment, per replica — and
//! surfaces in `RunMetrics` (`wire_up_bytes` / `wire_down_bytes`), the
//! sweep store, and the `diloco report --exp comm` table. The `netsim`
//! wall-clock model takes the same width via `WalltimeInput::outer_bits`.
//!
//! # Determinism rules
//!
//! - Stochastic rounding is seeded purely from
//!   `(run seed, sync index, replica id, range offset, block index)` —
//!   never from scheduling, wall-clock, or global state.
//! - Residuals and snapshots are per-replica state owned by the
//!   replica's pool worker, advancing only with the replica's own sync
//!   sequence.
//! - Reduction happens on the coordinator in replica-index order.
//!
//! Together these make every bit width reproduce bit-identically at
//! any `--workers` count (pinned by `tests/comm_codec.rs`).

pub mod codec;
pub mod encoder;
pub mod wire;

pub use codec::{codec_for, Codec, OuterBits};
pub use encoder::{CommState, SyncEncoder};
pub use wire::{SyncWireRecord, WireStats};
