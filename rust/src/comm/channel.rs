//! The direction-generic channel: one leg of the bidirectional comm
//! plane. A [`Channel`] bundles everything both wire directions share —
//! the codec, the flat-bus fragment geometry, the deterministic seed
//! discipline, and the error-feedback arithmetic — so the up-wire
//! (replica → coordinator, one logical stream per replica) and the
//! down-wire (coordinator → replica, a single broadcast stream) are the
//! *same* code instantiated twice, not two encoders that drift apart.
//!
//! The error-feedback contract, identical in both directions:
//!
//! ```text
//! x        = delta + residual        (the error-compensated payload)
//! wire     = encode(x, seed)
//! residual = x - decode(wire)        (carry this sync's error forward)
//! ```
//!
//! Only the meaning of `delta` differs: the up-wire ships
//! `snapshot - theta` (the replica's outer delta), the down-wire ships
//! `global - view` (how far the replicas' adopted view lags the
//! coordinator's freshly-stepped global). Because the error is carried,
//! the time-averaged wire value converges to the true value in both
//! directions — no quantization mass is ever lost, only deferred
//! (pinned by `tests/comm_codec.rs` for both legs).
//!
//! # Determinism
//!
//! Encode seeds are pure in `(run seed, direction, sync index, stream,
//! range offset)`, where `stream` is the replica id on the up-wire and
//! 0 on the down-wire (one broadcast stream for everyone). The
//! direction salt keeps the two legs' stochastic-rounding streams
//! disjoint even at the same sync index. Scheduling, worker count, and
//! wall-clock never enter. The up-wire derivation is byte-identical to
//! the pre-plane `SyncEncoder`, so lossy up-wire payloads are unchanged
//! by this refactor.

use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::FlatLayout;
use crate::util::rng::splitmix64;

use super::codec::Codec;

/// Which leg of the comm plane a channel drives. Enters the encode-seed
/// derivation so the two directions draw disjoint rounding streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Replica → coordinator: per-replica sync contributions.
    Up,
    /// Coordinator → replica: the broadcast of the refreshed global.
    Down,
}

impl Direction {
    /// Seed salt. `Up` keeps the pre-plane constant so lossy up-wire
    /// payloads are byte-identical across the refactor.
    fn salt(self) -> u64 {
        match self {
            Direction::Up => 0x5EED_C0DE,
            Direction::Down => 0xD0D0_5EED_C0DE,
        }
    }
}

/// One direction of a run's comm plane: the immutable recipe (layout +
/// codec + fragment count + run seed + direction) shared by every
/// thread that touches this leg. All mutable state — residuals, views,
/// arenas — lives with its owner (`ReplicaComm` / `WorkerComm` /
/// [`DownWire`]), never in the channel.
#[derive(Clone)]
pub struct Channel {
    layout: Arc<FlatLayout>,
    codec: Arc<dyn Codec>,
    fragments: usize,
    run_seed: u64,
    dir: Direction,
}

impl Channel {
    pub fn new(
        layout: Arc<FlatLayout>,
        codec: Arc<dyn Codec>,
        fragments: usize,
        run_seed: u64,
        dir: Direction,
    ) -> Channel {
        Channel {
            layout,
            codec,
            fragments: fragments.max(1),
            run_seed,
            dir,
        }
    }

    pub fn layout(&self) -> &Arc<FlatLayout> {
        &self.layout
    }

    pub fn codec(&self) -> &Arc<dyn Codec> {
        &self.codec
    }

    pub fn is_identity(&self) -> bool {
        self.codec.is_identity()
    }

    pub fn fragments(&self) -> usize {
        self.fragments
    }

    pub fn direction(&self) -> Direction {
        self.dir
    }

    /// The contiguous element ranges a sync of `frag` moves.
    pub fn ranges(&self, frag: Option<usize>) -> Vec<Range<usize>> {
        match frag {
            Some(f) => self.layout.fragment_ranges(self.fragments, f),
            None => self.layout.full_range(),
        }
    }

    /// Exact wire size of one payload on this leg for a sync of `frag`
    /// (per replica on the up-wire; total on the down-wire, which is a
    /// single broadcast stream).
    pub fn payload_bytes(&self, frag: Option<usize>) -> usize {
        self.ranges(frag)
            .iter()
            .map(|r| self.codec.wire_bytes(r.len()))
            .sum()
    }

    /// Deterministic encode seed: pure in (run seed, direction, sync
    /// index, stream, range offset) — never scheduling.
    fn seed_for(&self, sync_index: u64, stream: u64, range_start: usize) -> u64 {
        let mut s = self.run_seed ^ self.dir.salt();
        let a = splitmix64(&mut s);
        let mut s = a ^ sync_index;
        let b = splitmix64(&mut s);
        let mut s = b ^ (stream << 32) ^ range_start as u64;
        splitmix64(&mut s)
    }

    /// Encode `src`'s due ranges verbatim — the identity leg's raw-f32
    /// payload (the exact legacy wire when the codec is [`super::codec::Fp32`]).
    pub fn encode_raw(
        &self,
        src: &[f32],
        frag: Option<usize>,
        sync_index: u64,
        stream: u64,
    ) -> Vec<u8> {
        let ranges = self.ranges(frag);
        let mut out = Vec::with_capacity(self.payload_bytes(frag));
        for r in &ranges {
            let seed = self.seed_for(sync_index, stream, r.start);
            self.codec.encode(&src[r.clone()], seed, &mut out);
        }
        out
    }

    /// Error-feedback encode of the due ranges. On entry `staging`
    /// holds the raw delta; the channel forms `x = delta + residual`,
    /// encodes it, and updates `residual <- x - dq(x)`. On exit
    /// `staging` holds `dq(x)` — what the receiving side will decode —
    /// so the caller can advance its view by exactly what went out.
    pub fn encode_ef(
        &self,
        staging: &mut [f32],
        residual: &mut [f32],
        frag: Option<usize>,
        sync_index: u64,
        stream: u64,
    ) -> Result<Vec<u8>> {
        let ranges = self.ranges(frag);
        let mut out = Vec::with_capacity(self.payload_bytes(frag));
        for r in &ranges {
            for i in r.clone() {
                staging[i] += residual[i];
                // residual temporarily holds x until dq(x) lands below
                residual[i] = staging[i];
            }
            let seed = self.seed_for(sync_index, stream, r.start);
            let before = out.len();
            self.codec.encode(&staging[r.clone()], seed, &mut out);
            self.codec.decode(&out[before..], &mut staging[r.clone()])?;
            for i in r.clone() {
                residual[i] -= staging[i];
            }
        }
        Ok(out)
    }

    /// Decode one payload of this leg into `dst` over the due ranges
    /// (everything outside them is untouched).
    pub fn decode(&self, wire: &[u8], frag: Option<usize>, dst: &mut [f32]) -> Result<()> {
        let ranges = self.ranges(frag);
        let expected: usize = ranges.iter().map(|r| self.codec.wire_bytes(r.len())).sum();
        if wire.len() != expected {
            bail!(
                "{:?}-channel decode: {} payload bytes, expected {expected}",
                self.dir,
                wire.len()
            );
        }
        let mut off = 0usize;
        for r in &ranges {
            let nb = self.codec.wire_bytes(r.len());
            self.codec.decode(&wire[off..off + nb], &mut dst[r.clone()])?;
            off += nb;
        }
        Ok(())
    }
}

/// The coordinator-owned state of the down-wire: the replicas' current
/// `view` of the global (what every replica's snapshot holds — the
/// broadcast is one stream, so one arena covers all M replicas) and the
/// broadcast's own error-feedback `residual`. Identity down-wires
/// allocate none of this — they keep the zero-copy `Arc` literal
/// handoff and this struct is never built.
pub struct DownWire {
    chan: Channel,
    view: Vec<f32>,
    residual: Vec<f32>,
    staging: Vec<f32>,
}

impl DownWire {
    /// `init` is the initial global (Algorithm 1 line 2: every replica
    /// starts exactly there, so the view starts exact).
    pub fn new(chan: Channel, init: &[f32]) -> DownWire {
        let total = chan.layout().total();
        // a wrong-sized init would build an undersized view that
        // panics opaquely mid-broadcast — refuse in release builds too
        // (same policy as CommLink::new)
        assert_eq!(
            init.len(),
            total,
            "down wire: init must be the full flat arena"
        );
        DownWire {
            chan,
            view: init.to_vec(),
            residual: vec![0.0; total],
            staging: vec![0.0; total],
        }
    }

    pub fn chan(&self) -> &Channel {
        &self.chan
    }

    /// What the replicas currently hold for the global (exposed for
    /// tests: the time-average of this converges to the true global).
    pub fn view(&self) -> &[f32] {
        &self.view
    }

    /// The broadcast error carried into the next sync.
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Coordinator-side down-wire arena footprint in bytes.
    pub fn arena_bytes(&self) -> u64 {
        4 * (self.view.len() + self.residual.len() + self.staging.len()) as u64
    }

    /// Restore view + residual from a checkpoint. The pair IS the
    /// broadcast stream's whole mutable state, so a restored wire
    /// continues the EF sequence bit-identically (encode seeds are pure
    /// in the sync index, which [`super::super::WireStats`] carries).
    pub fn restore(&mut self, view: &[f32], residual: &[f32]) -> Result<()> {
        if view.len() != self.view.len() || residual.len() != self.residual.len() {
            bail!(
                "down wire restore: got view {} / residual {}, expected {} each",
                view.len(),
                residual.len(),
                self.view.len()
            );
        }
        self.view.copy_from_slice(view);
        self.residual.copy_from_slice(residual);
        Ok(())
    }

    /// Encode the refreshed global's due fragment **once** for all
    /// replicas: `x = (global - view) + residual`, error-compensated
    /// like the up-wire. Advances the view by exactly `dq(x)` — the
    /// value every worker will decode — so coordinator and workers
    /// stay bit-identical views of the same stream.
    pub fn encode_broadcast(
        &mut self,
        global: &[f32],
        frag: Option<usize>,
        sync_index: u64,
    ) -> Result<Vec<u8>> {
        let ranges = self.chan.ranges(frag);
        for r in &ranges {
            for i in r.clone() {
                self.staging[i] = global[i] - self.view[i];
            }
        }
        let bytes = self
            .chan
            .encode_ef(&mut self.staging, &mut self.residual, frag, sync_index, 0)?;
        for r in &ranges {
            for i in r.clone() {
                self.view[i] += self.staging[i];
            }
        }
        Ok(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::{codec_for, OuterBits};

    fn layout() -> Arc<FlatLayout> {
        Arc::new(FlatLayout::new(vec![vec![3], vec![2, 2], vec![5]]))
    }

    fn chan(bits: OuterBits, dir: Direction) -> Channel {
        Channel::new(layout(), codec_for(bits), 2, 9, dir)
    }

    #[test]
    fn directions_draw_disjoint_seed_streams() {
        let up = chan(OuterBits::Int4, Direction::Up);
        let down = chan(OuterBits::Int4, Direction::Down);
        assert_ne!(up.seed_for(0, 0, 0), down.seed_for(0, 0, 0));
        // and within a direction, seeds vary by sync, stream, offset
        let base = up.seed_for(0, 0, 0);
        assert_ne!(base, up.seed_for(1, 0, 0));
        assert_ne!(base, up.seed_for(0, 1, 0));
        assert_ne!(base, up.seed_for(0, 0, 8));
    }

    #[test]
    fn payload_bytes_match_fragment_ranges() {
        for bits in OuterBits::ALL {
            let c = chan(bits, Direction::Down);
            let full = c.payload_bytes(None);
            let f0 = c.payload_bytes(Some(0));
            let f1 = c.payload_bytes(Some(1));
            assert!(f0 > 0 && f1 > 0, "{bits:?}");
            assert!(f0 < full && f1 < full, "{bits:?}");
        }
    }

    #[test]
    fn raw_roundtrips_through_decode() {
        let c = chan(OuterBits::Fp32, Direction::Down);
        let total = c.layout().total();
        let src: Vec<f32> = (0..total).map(|i| i as f32 * 0.25 - 1.5).collect();
        let wire = c.encode_raw(&src, Some(1), 3, 0);
        assert_eq!(wire.len(), c.payload_bytes(Some(1)));
        let mut dst = vec![0.0f32; total];
        c.decode(&wire, Some(1), &mut dst).unwrap();
        for r in c.ranges(Some(1)) {
            for i in r {
                assert_eq!(dst[i].to_bits(), src[i].to_bits());
            }
        }
        // short payloads are rejected
        assert!(c.decode(&wire[1..], Some(1), &mut dst).is_err());
    }

    #[test]
    fn encode_ef_leaves_dq_in_staging_and_error_in_residual() {
        let c = chan(OuterBits::Int4, Direction::Down);
        let total = c.layout().total();
        let delta: Vec<f32> = (0..total).map(|i| ((i as f32) * 0.7).sin()).collect();
        let mut staging = delta.clone();
        let mut residual = vec![0.0f32; total];
        let wire = c.encode_ef(&mut staging, &mut residual, None, 0, 0).unwrap();
        let mut dq = vec![0.0f32; total];
        c.decode(&wire, None, &mut dq).unwrap();
        for i in 0..total {
            assert_eq!(staging[i].to_bits(), dq[i].to_bits(), "staging must hold dq");
            assert!(
                (delta[i] - (dq[i] + residual[i])).abs() < 1e-6,
                "x = dq + residual must reconstruct the delta at {i}"
            );
        }
    }

    #[test]
    fn down_wire_view_tracks_global_within_one_step() {
        let total = layout().total();
        let init: Vec<f32> = vec![0.0; total];
        let mut dw = DownWire::new(
            Channel::new(layout(), codec_for(OuterBits::Int8), 1, 7, Direction::Down),
            &init,
        );
        let global: Vec<f32> = (0..total).map(|i| (i as f32 - 4.0) * 0.3).collect();
        let bytes = dw.encode_broadcast(&global, None, 0).unwrap();
        assert_eq!(bytes.len(), dw.chan().payload_bytes(None));
        let maxabs = global.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let step = maxabs / 127.0;
        for (v, g) in dw.view().iter().zip(&global) {
            assert!((v - g).abs() <= step * 1.0001, "{v} vs {g}");
        }
        // coordinator-side footprint: exactly 3 full-size f32 arenas
        // (view + residual + staging), pinned so growth is deliberate
        assert_eq!(dw.arena_bytes(), 3 * total as u64 * 4);
    }
}
