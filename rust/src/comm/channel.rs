//! The direction-generic channel: one leg of the bidirectional comm
//! plane. A [`Channel`] bundles everything both wire directions share —
//! the codec, the flat-bus fragment geometry, the deterministic seed
//! discipline, and the error-feedback arithmetic — so the up-wire
//! (replica → coordinator, one logical stream per replica) and the
//! down-wire (coordinator → replica, a single broadcast stream) are the
//! *same* code instantiated twice, not two encoders that drift apart.
//!
//! The error-feedback contract, identical in both directions:
//!
//! ```text
//! x        = delta + residual        (the error-compensated payload)
//! wire     = encode(x, seed)
//! residual = x - decode(wire)        (carry this sync's error forward)
//! ```
//!
//! Only the meaning of `delta` differs: the up-wire ships
//! `snapshot - theta` (the replica's outer delta), the down-wire ships
//! `global - view` (how far the replicas' adopted view lags the
//! coordinator's freshly-stepped global). Because the error is carried,
//! the time-averaged wire value converges to the true value in both
//! directions — no quantization mass is ever lost, only deferred
//! (pinned by `tests/comm_codec.rs` for both legs).
//!
//! # Determinism
//!
//! Encode seeds are pure in `(run seed, direction, sync index, stream,
//! range offset)`, where `stream` is the replica id on the up-wire and
//! 0 on the down-wire (one broadcast stream for everyone). The
//! direction salt keeps the two legs' stochastic-rounding streams
//! disjoint even at the same sync index. Scheduling, worker count, and
//! wall-clock never enter. The up-wire derivation is byte-identical to
//! the pre-plane `SyncEncoder`, so lossy up-wire payloads are unchanged
//! by this refactor.

use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::FlatLayout;
use crate::transport::frame::WireBuf;
use crate::util::par::{self, Piece};
use crate::util::rng::splitmix64;

use super::codec::{Codec, BLOCK};

/// Which leg of the comm plane a channel drives. Enters the encode-seed
/// derivation so the two directions draw disjoint rounding streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Replica → coordinator: per-replica sync contributions.
    Up,
    /// Coordinator → replica: the broadcast of the refreshed global.
    Down,
}

impl Direction {
    /// Seed salt. `Up` keeps the pre-plane constant so lossy up-wire
    /// payloads are byte-identical across the refactor.
    fn salt(self) -> u64 {
        match self {
            Direction::Up => 0x5EED_C0DE,
            Direction::Down => 0xD0D0_5EED_C0DE,
        }
    }
}

/// One direction of a run's comm plane: the immutable recipe (layout +
/// codec + fragment count + run seed + direction) shared by every
/// thread that touches this leg. All mutable state — residuals, views,
/// arenas — lives with its owner (`ReplicaComm` / `WorkerComm` /
/// [`DownWire`]), never in the channel.
#[derive(Clone)]
pub struct Channel {
    layout: Arc<FlatLayout>,
    codec: Arc<dyn Codec>,
    fragments: usize,
    run_seed: u64,
    dir: Direction,
}

impl Channel {
    pub fn new(
        layout: Arc<FlatLayout>,
        codec: Arc<dyn Codec>,
        fragments: usize,
        run_seed: u64,
        dir: Direction,
    ) -> Channel {
        Channel {
            layout,
            codec,
            fragments: fragments.max(1),
            run_seed,
            dir,
        }
    }

    pub fn layout(&self) -> &Arc<FlatLayout> {
        &self.layout
    }

    pub fn codec(&self) -> &Arc<dyn Codec> {
        &self.codec
    }

    pub fn is_identity(&self) -> bool {
        self.codec.is_identity()
    }

    pub fn fragments(&self) -> usize {
        self.fragments
    }

    pub fn direction(&self) -> Direction {
        self.dir
    }

    /// The contiguous element ranges a sync of `frag` moves.
    pub fn ranges(&self, frag: Option<usize>) -> Vec<Range<usize>> {
        match frag {
            Some(f) => self.layout.fragment_ranges(self.fragments, f),
            None => self.layout.full_range(),
        }
    }

    /// Exact wire size of one payload on this leg for a sync of `frag`
    /// (per replica on the up-wire; total on the down-wire, which is a
    /// single broadcast stream).
    pub fn payload_bytes(&self, frag: Option<usize>) -> usize {
        self.ranges(frag)
            .iter()
            .map(|r| self.codec.wire_bytes(r.len()))
            .sum()
    }

    /// Deterministic encode seed: pure in (run seed, direction, sync
    /// index, stream, range offset) — never scheduling.
    fn seed_for(&self, sync_index: u64, stream: u64, range_start: usize) -> u64 {
        let mut s = self.run_seed ^ self.dir.salt();
        let a = splitmix64(&mut s);
        let mut s = a ^ sync_index;
        let b = splitmix64(&mut s);
        let mut s = b ^ (stream << 32) ^ range_start as u64;
        splitmix64(&mut s)
    }

    /// Encode `src`'s due ranges verbatim — the identity leg's raw-f32
    /// payload (the exact legacy wire when the codec is [`super::codec::Fp32`]).
    pub fn encode_raw(
        &self,
        src: &[f32],
        frag: Option<usize>,
        sync_index: u64,
        stream: u64,
    ) -> WireBuf {
        let mut out = WireBuf::new();
        self.encode_raw_into(src, frag, sync_index, stream, &mut out);
        out
    }

    /// [`Channel::encode_raw`] into a caller-owned (typically recycled)
    /// wire buffer: one exact-size reservation per payload, no
    /// per-range growth. The payload lands after the buffer's reserved
    /// frame prefix, so a transport can stamp the header in place and
    /// ship without any assembly copy.
    pub fn encode_raw_into(
        &self,
        src: &[f32],
        frag: Option<usize>,
        sync_index: u64,
        stream: u64,
        out: &mut WireBuf,
    ) {
        out.reset();
        let ranges = self.ranges(frag);
        let payload_bytes = self.payload_bytes(frag);
        let v = out.vec_for_append();
        v.reserve(payload_bytes);
        for r in &ranges {
            let seed = self.seed_for(sync_index, stream, r.start);
            self.codec.encode(&src[r.clone()], seed, v);
        }
    }

    /// Error-feedback encode of the due ranges. On entry `staging`
    /// holds the raw delta; the channel forms `x = delta + residual`,
    /// encodes it, and updates `residual <- x - dq(x)`. On exit
    /// `staging` holds `dq(x)` — what the receiving side will decode —
    /// so the caller can advance its view by exactly what went out.
    pub fn encode_ef(
        &self,
        staging: &mut [f32],
        residual: &mut [f32],
        frag: Option<usize>,
        sync_index: u64,
        stream: u64,
    ) -> Result<WireBuf> {
        let mut out = WireBuf::new();
        self.encode_ef_into(staging, residual, frag, sync_index, stream, 1, &mut out)?;
        Ok(out)
    }

    /// [`Channel::encode_ef`] into a caller-owned buffer, sharded over
    /// up to `threads` scoped threads. The due ranges are cut into
    /// block-aligned pieces with deterministic ownership
    /// (`util::par::shard_ranges`); each piece runs the full EF
    /// sequence (carry-in, encode, decode-back, carry-out) on one
    /// thread, with stochastic-rounding children drawn per absolute
    /// block ([`Codec::encode_at`]) — so the payload bytes and both
    /// arenas are byte/bit-identical at any thread count (pinned by
    /// `tests/comm_codec.rs`).
    #[allow(clippy::too_many_arguments)]
    pub fn encode_ef_into(
        &self,
        staging: &mut [f32],
        residual: &mut [f32],
        frag: Option<usize>,
        sync_index: u64,
        stream: u64,
        threads: usize,
        out: &mut WireBuf,
    ) -> Result<()> {
        let ranges = self.ranges(frag);
        out.reset();
        out.resize_payload(self.payload_bytes(frag));
        let items = shard_items(
            self,
            &ranges,
            threads,
            out.payload_mut(),
            staging,
            residual,
        );
        let ranges = &ranges;
        par::map_shards(items, |_, (pieces, wires, stages, resids)| -> Result<()> {
            self.encode_shard(ranges, sync_index, stream, &pieces, wires, stages, resids)?;
            Ok(())
        })
        .into_iter()
        .collect::<Result<()>>()
    }

    /// [`Channel::encode_ef_into`] with streaming flushes: the payload
    /// is still produced shard-by-shard over up to `threads` scoped
    /// threads, but completed shards are handed to `flush` **in payload
    /// order as they finish** — a transport can push early bytes onto
    /// the socket while later shards are still encoding. The
    /// concatenation of the flushed chunks is byte-identical to the
    /// one-shot payload (`out` holds the same full payload on return),
    /// and the EF arenas end bit-identical at any thread count — the
    /// per-shard arithmetic is the exact same helper.
    ///
    /// On `Err` (a failed flush is a dead transport) the EF arenas are
    /// partially advanced and must be treated as poisoned — callers
    /// abandon the run, never retry the sync.
    #[allow(clippy::too_many_arguments)]
    pub fn encode_ef_chunked(
        &self,
        staging: &mut [f32],
        residual: &mut [f32],
        frag: Option<usize>,
        sync_index: u64,
        stream: u64,
        threads: usize,
        out: &mut WireBuf,
        flush: &mut dyn FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        let ranges = self.ranges(frag);
        out.reset();
        out.resize_payload(self.payload_bytes(frag));
        let items = shard_items(
            self,
            &ranges,
            threads,
            out.payload_mut(),
            staging,
            residual,
        );
        let n = items.len();
        if n <= 1 {
            // degenerate sharding runs inline (mirrors par::map_shards)
            for (pieces, wires, stages, resids) in items {
                let views =
                    self.encode_shard(&ranges, sync_index, stream, &pieces, wires, stages, resids)?;
                for v in views {
                    flush(v)?;
                }
            }
            return Ok(());
        }
        let ranges = &ranges;
        std::thread::scope(|scope| -> Result<()> {
            let (tx, rx) = std::sync::mpsc::channel();
            for (k, (pieces, wires, stages, resids)) in items.into_iter().enumerate() {
                let tx = tx.clone();
                scope.spawn(move || {
                    let views =
                        self.encode_shard(ranges, sync_index, stream, &pieces, wires, stages, resids);
                    // a send failure means the flush loop bailed early;
                    // the error that caused it is already on its way up
                    let _ = tx.send((k, views));
                });
            }
            drop(tx);
            // flush completed shards in payload order: shard k+1 may
            // finish first, but its bytes wait until k has gone out
            let mut pending: Vec<Option<Vec<&[u8]>>> = (0..n).map(|_| None).collect();
            let mut next = 0usize;
            for _ in 0..n {
                let (k, views) = rx.recv().expect("encode shard thread vanished");
                pending[k] = Some(views?);
                while next < n {
                    let Some(views) = pending[next].take() else {
                        break;
                    };
                    for v in views {
                        flush(v)?;
                    }
                    next += 1;
                }
            }
            Ok(())
        })
    }

    /// [`Channel::encode_ef_into`] cut into up to `chunks` block-aligned
    /// chunks, each handed to `flush` as `(wire-byte offset, bytes)` the
    /// moment it is encoded — **sequentially, on the caller's thread**.
    /// This is the worker-side up-leg streamer: a pool worker's cores
    /// are already saturated by its siblings, so unlike
    /// [`Channel::encode_ef_chunked`] it spawns nothing; the win is
    /// overlapping the socket with the *remaining* chunks' encode.
    /// Chunks are flushed in payload order with contiguous offsets
    /// (chunk k+1 starts where k ended, the first at 0), and their
    /// concatenation is byte-identical to the one-shot payload at any
    /// chunk count — the cuts ride the same block-aligned shard
    /// partition the thread-count-invariance tests pin.
    ///
    /// On `Err` (a failed flush is a dead transport) the EF arenas are
    /// partially advanced and must be treated as poisoned — callers
    /// abandon the run, never retry the sync.
    #[allow(clippy::too_many_arguments)]
    pub fn encode_ef_streamed(
        &self,
        staging: &mut [f32],
        residual: &mut [f32],
        frag: Option<usize>,
        sync_index: u64,
        stream: u64,
        chunks: usize,
        out: &mut WireBuf,
        flush: &mut dyn FnMut(usize, &[u8]) -> Result<()>,
    ) -> Result<()> {
        let ranges = self.ranges(frag);
        out.reset();
        out.resize_payload(self.payload_bytes(frag));
        let items = shard_items(
            self,
            &ranges,
            chunks,
            out.payload_mut(),
            staging,
            residual,
        );
        let mut off = 0usize;
        for (pieces, wires, stages, resids) in items {
            let views =
                self.encode_shard(&ranges, sync_index, stream, &pieces, wires, stages, resids)?;
            for v in views {
                flush(off, v)?;
                off += v.len();
            }
        }
        Ok(())
    }

    /// One shard's error-feedback encode — the single implementation
    /// both the fork-join and the streaming paths run, so their bytes
    /// cannot drift. Returns the shard's wire views downgraded to
    /// shared slices (the streaming path flushes them; the fork-join
    /// path drops them).
    #[allow(clippy::too_many_arguments)]
    fn encode_shard<'a>(
        &self,
        ranges: &[Range<usize>],
        sync_index: u64,
        stream: u64,
        pieces: &[Piece],
        wires: Vec<&'a mut [u8]>,
        stages: Vec<&mut [f32]>,
        resids: Vec<&mut [f32]>,
    ) -> Result<Vec<&'a [u8]>> {
        let mut views: Vec<&'a [u8]> = Vec::with_capacity(pieces.len());
        for (((p, wire), stage), resid) in pieces.iter().zip(wires).zip(stages).zip(resids) {
            let src = &ranges[p.src];
            let seed = self.seed_for(sync_index, stream, src.start);
            let block_off = ((p.range.start - src.start) / BLOCK) as u64;
            for (s, r) in stage.iter_mut().zip(resid.iter_mut()) {
                *s += *r;
                // residual temporarily holds x until dq(x) lands
                *r = *s;
            }
            self.codec.encode_at(stage, seed, block_off, &mut wire[..]);
            self.codec.decode(&wire[..], &mut stage[..])?;
            for (r, s) in resid.iter_mut().zip(stage.iter()) {
                *r -= *s;
            }
            views.push(wire);
        }
        Ok(views)
    }

    /// Decode one payload of this leg into `dst` over the due ranges
    /// (everything outside them is untouched).
    pub fn decode(&self, wire: &[u8], frag: Option<usize>, dst: &mut [f32]) -> Result<()> {
        let ranges = self.ranges(frag);
        let expected: usize = ranges.iter().map(|r| self.codec.wire_bytes(r.len())).sum();
        if wire.len() != expected {
            bail!(
                "{:?}-channel decode: {} payload bytes, expected {expected}",
                self.dir,
                wire.len()
            );
        }
        let mut off = 0usize;
        for r in &ranges {
            let nb = self.codec.wire_bytes(r.len());
            self.codec.decode(&wire[off..off + nb], &mut dst[r.clone()])?;
            off += nb;
        }
        Ok(())
    }
}

/// The per-shard work items of one EF encode: deterministic
/// block-aligned pieces plus matching disjoint views of the payload
/// and both arenas (shared by the fork-join and streaming paths).
type ShardItem<'a> = (
    Vec<Piece>,
    Vec<&'a mut [u8]>,
    Vec<&'a mut [f32]>,
    Vec<&'a mut [f32]>,
);

fn shard_items<'a>(
    chan: &Channel,
    ranges: &[Range<usize>],
    threads: usize,
    payload: &'a mut [u8],
    staging: &'a mut [f32],
    residual: &'a mut [f32],
) -> Vec<ShardItem<'a>> {
    // wire offset of each source range within the payload
    let mut range_off = Vec::with_capacity(ranges.len());
    let mut off = 0usize;
    for r in ranges {
        range_off.push(off);
        off += chan.codec.wire_bytes(r.len());
    }
    let shards = par::shard_ranges(ranges, threads, BLOCK);
    let wires = split_wire(payload, &shards, ranges, &range_off, chan.codec.as_ref());
    let stages = par::split_pieces(staging, &shards);
    let resids = par::split_pieces(residual, &shards);
    shards
        .into_iter()
        .zip(wires)
        .zip(stages)
        .zip(resids)
        .map(|(((pieces, w), s), r)| (pieces, w, s, r))
        .collect()
}

/// Split a payload buffer into per-shard, per-piece wire views
/// mirroring an element sharding. A piece's wire slice starts at its
/// source range's payload offset plus the encoded size of the
/// elements before it — exact because pieces start block-aligned, so
/// `wire_bytes` is additive at every cut; its length is
/// `wire_bytes(piece.len())` (only the last piece of a range can
/// carry the ragged tail).
fn split_wire<'a>(
    wire: &'a mut [u8],
    shards: &[Vec<Piece>],
    ranges: &[Range<usize>],
    range_off: &[usize],
    codec: &dyn Codec,
) -> Vec<Vec<&'a mut [u8]>> {
    let mut rest = wire;
    let mut base = 0usize;
    let mut out = Vec::with_capacity(shards.len());
    for shard in shards {
        let mut views = Vec::with_capacity(shard.len());
        for p in shard {
            let start = range_off[p.src] + codec.wire_bytes(p.range.start - ranges[p.src].start);
            let len = codec.wire_bytes(p.len());
            let tail = std::mem::take(&mut rest);
            let (seg, tail) = tail[start - base..].split_at_mut(len);
            views.push(seg);
            rest = tail;
            base = start + len;
        }
        out.push(views);
    }
    out
}

/// The coordinator-owned state of the down-wire: the replicas' current
/// `view` of the global (what every replica's snapshot holds — the
/// broadcast is one stream, so one arena covers all M replicas) and the
/// broadcast's own error-feedback `residual`. Identity down-wires
/// allocate none of this — they keep the zero-copy `Arc` literal
/// handoff and this struct is never built.
pub struct DownWire {
    chan: Channel,
    view: Vec<f32>,
    residual: Vec<f32>,
    staging: Vec<f32>,
}

impl DownWire {
    /// `init` is the initial global (Algorithm 1 line 2: every replica
    /// starts exactly there, so the view starts exact).
    pub fn new(chan: Channel, init: &[f32]) -> DownWire {
        let total = chan.layout().total();
        // a wrong-sized init would build an undersized view that
        // panics opaquely mid-broadcast — refuse in release builds too
        // (same policy as CommLink::new)
        assert_eq!(
            init.len(),
            total,
            "down wire: init must be the full flat arena"
        );
        DownWire {
            chan,
            view: init.to_vec(),
            residual: vec![0.0; total],
            staging: vec![0.0; total],
        }
    }

    pub fn chan(&self) -> &Channel {
        &self.chan
    }

    /// What the replicas currently hold for the global (exposed for
    /// tests: the time-average of this converges to the true global).
    pub fn view(&self) -> &[f32] {
        &self.view
    }

    /// The broadcast error carried into the next sync.
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Coordinator-side down-wire arena footprint in bytes.
    pub fn arena_bytes(&self) -> u64 {
        4 * (self.view.len() + self.residual.len() + self.staging.len()) as u64
    }

    /// Restore view + residual from a checkpoint. The pair IS the
    /// broadcast stream's whole mutable state, so a restored wire
    /// continues the EF sequence bit-identically (encode seeds are pure
    /// in the sync index, which [`super::super::WireStats`] carries).
    pub fn restore(&mut self, view: &[f32], residual: &[f32]) -> Result<()> {
        if view.len() != self.view.len() || residual.len() != self.residual.len() {
            bail!(
                "down wire restore: got view {} / residual {}, expected {} each",
                view.len(),
                residual.len(),
                self.view.len()
            );
        }
        self.view.copy_from_slice(view);
        self.residual.copy_from_slice(residual);
        Ok(())
    }

    /// Encode the refreshed global's due fragment **once** for all
    /// replicas: `x = (global - view) + residual`, error-compensated
    /// like the up-wire. Advances the view by exactly `dq(x)` — the
    /// value every worker will decode — so coordinator and workers
    /// stay bit-identical views of the same stream.
    pub fn encode_broadcast(
        &mut self,
        global: &[f32],
        frag: Option<usize>,
        sync_index: u64,
    ) -> Result<WireBuf> {
        let mut out = WireBuf::new();
        self.encode_broadcast_into(global, frag, sync_index, 1, &mut out)?;
        Ok(out)
    }

    /// [`DownWire::encode_broadcast`] into a caller-owned (typically
    /// recycled) wire buffer, with the EF encode sharded over up to
    /// `threads` scoped threads ([`Channel::encode_ef_into`]) —
    /// byte-identical at any thread count.
    pub fn encode_broadcast_into(
        &mut self,
        global: &[f32],
        frag: Option<usize>,
        sync_index: u64,
        threads: usize,
        out: &mut WireBuf,
    ) -> Result<()> {
        self.stage_delta(global, frag);
        self.chan.encode_ef_into(
            &mut self.staging,
            &mut self.residual,
            frag,
            sync_index,
            0,
            threads,
            out,
        )?;
        self.advance_view(frag);
        Ok(())
    }

    /// [`DownWire::encode_broadcast_into`] with streaming flushes
    /// ([`Channel::encode_ef_chunked`]): completed encode shards are
    /// handed to `flush` in payload order while later shards are still
    /// encoding, so a transport overlaps broadcast encode with socket
    /// writes. Flushed bytes concatenate to exactly the one-shot
    /// payload; on `Err` the wire state is poisoned (the sync was
    /// half-shipped) and the run must be abandoned.
    pub fn encode_broadcast_chunked(
        &mut self,
        global: &[f32],
        frag: Option<usize>,
        sync_index: u64,
        threads: usize,
        out: &mut WireBuf,
        flush: &mut dyn FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        self.stage_delta(global, frag);
        self.chan.encode_ef_chunked(
            &mut self.staging,
            &mut self.residual,
            frag,
            sync_index,
            0,
            threads,
            out,
            flush,
        )?;
        self.advance_view(frag);
        Ok(())
    }

    /// Stage `global - view` over the due ranges (the broadcast's raw
    /// delta, before error compensation).
    fn stage_delta(&mut self, global: &[f32], frag: Option<usize>) {
        for r in &self.chan.ranges(frag) {
            for i in r.clone() {
                self.staging[i] = global[i] - self.view[i];
            }
        }
    }

    /// Advance the view by `dq(x)` — what every worker will decode —
    /// which the EF encode left in staging.
    fn advance_view(&mut self, frag: Option<usize>) {
        for r in &self.chan.ranges(frag) {
            for i in r.clone() {
                self.view[i] += self.staging[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::{codec_for, OuterBits};

    fn layout() -> Arc<FlatLayout> {
        Arc::new(FlatLayout::new(vec![vec![3], vec![2, 2], vec![5]]))
    }

    fn chan(bits: OuterBits, dir: Direction) -> Channel {
        Channel::new(layout(), codec_for(bits), 2, 9, dir)
    }

    #[test]
    fn directions_draw_disjoint_seed_streams() {
        let up = chan(OuterBits::Int4, Direction::Up);
        let down = chan(OuterBits::Int4, Direction::Down);
        assert_ne!(up.seed_for(0, 0, 0), down.seed_for(0, 0, 0));
        // and within a direction, seeds vary by sync, stream, offset
        let base = up.seed_for(0, 0, 0);
        assert_ne!(base, up.seed_for(1, 0, 0));
        assert_ne!(base, up.seed_for(0, 1, 0));
        assert_ne!(base, up.seed_for(0, 0, 8));
    }

    #[test]
    fn payload_bytes_match_fragment_ranges() {
        for bits in OuterBits::ALL {
            let c = chan(bits, Direction::Down);
            let full = c.payload_bytes(None);
            let f0 = c.payload_bytes(Some(0));
            let f1 = c.payload_bytes(Some(1));
            assert!(f0 > 0 && f1 > 0, "{bits:?}");
            assert!(f0 < full && f1 < full, "{bits:?}");
        }
    }

    #[test]
    fn raw_roundtrips_through_decode() {
        let c = chan(OuterBits::Fp32, Direction::Down);
        let total = c.layout().total();
        let src: Vec<f32> = (0..total).map(|i| i as f32 * 0.25 - 1.5).collect();
        let wire = c.encode_raw(&src, Some(1), 3, 0);
        assert_eq!(wire.payload_len(), c.payload_bytes(Some(1)));
        let mut dst = vec![0.0f32; total];
        c.decode(wire.payload(), Some(1), &mut dst).unwrap();
        for r in c.ranges(Some(1)) {
            for i in r {
                assert_eq!(dst[i].to_bits(), src[i].to_bits());
            }
        }
        // short payloads are rejected
        assert!(c.decode(&wire.payload()[1..], Some(1), &mut dst).is_err());
    }

    #[test]
    fn encode_ef_leaves_dq_in_staging_and_error_in_residual() {
        let c = chan(OuterBits::Int4, Direction::Down);
        let total = c.layout().total();
        let delta: Vec<f32> = (0..total).map(|i| ((i as f32) * 0.7).sin()).collect();
        let mut staging = delta.clone();
        let mut residual = vec![0.0f32; total];
        let wire = c.encode_ef(&mut staging, &mut residual, None, 0, 0).unwrap();
        let mut dq = vec![0.0f32; total];
        c.decode(wire.payload(), None, &mut dq).unwrap();
        for i in 0..total {
            assert_eq!(staging[i].to_bits(), dq[i].to_bits(), "staging must hold dq");
            assert!(
                (delta[i] - (dq[i] + residual[i])).abs() < 1e-6,
                "x = dq + residual must reconstruct the delta at {i}"
            );
        }
    }

    #[test]
    fn encode_ef_into_is_thread_count_invariant() {
        // multi-block leaves so the shard cutter actually cuts
        let layout = Arc::new(FlatLayout::new(vec![vec![700], vec![300, 2], vec![513]]));
        let total = layout.total();
        let delta: Vec<f32> = (0..total).map(|i| ((i as f32) * 0.37).sin()).collect();
        let resid0: Vec<f32> = (0..total).map(|i| (i as f32 * 0.001) - 0.9).collect();
        for bits in OuterBits::ALL {
            let c = Channel::new(layout.clone(), codec_for(bits), 2, 11, Direction::Up);
            let mut base_wire = WireBuf::new();
            let mut base_stage = delta.clone();
            let mut base_resid = resid0.clone();
            c.encode_ef_into(&mut base_stage, &mut base_resid, Some(1), 4, 2, 1, &mut base_wire)
                .unwrap();
            for threads in [2, 3, 8, 64] {
                // dirty recycled buffer: reuse must rewrite every byte
                let mut wire = WireBuf::from_payload(&[0xAAu8; 5]);
                let mut stage = delta.clone();
                let mut resid = resid0.clone();
                c.encode_ef_into(&mut stage, &mut resid, Some(1), 4, 2, threads, &mut wire)
                    .unwrap();
                assert_eq!(wire.payload(), base_wire.payload(), "{bits:?} threads={threads}");
                for i in 0..total {
                    assert_eq!(
                        stage[i].to_bits(),
                        base_stage[i].to_bits(),
                        "{bits:?} threads={threads} staging[{i}]"
                    );
                    assert_eq!(
                        resid[i].to_bits(),
                        base_resid[i].to_bits(),
                        "{bits:?} threads={threads} residual[{i}]"
                    );
                }
            }
        }
    }

    #[test]
    fn down_wire_view_tracks_global_within_one_step() {
        let total = layout().total();
        let init: Vec<f32> = vec![0.0; total];
        let mut dw = DownWire::new(
            Channel::new(layout(), codec_for(OuterBits::Int8), 1, 7, Direction::Down),
            &init,
        );
        let global: Vec<f32> = (0..total).map(|i| (i as f32 - 4.0) * 0.3).collect();
        let bytes = dw.encode_broadcast(&global, None, 0).unwrap();
        assert_eq!(bytes.payload_len(), dw.chan().payload_bytes(None));
        let maxabs = global.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let step = maxabs / 127.0;
        for (v, g) in dw.view().iter().zip(&global) {
            assert!((v - g).abs() <= step * 1.0001, "{v} vs {g}");
        }
        // coordinator-side footprint: exactly 3 full-size f32 arenas
        // (view + residual + staging), pinned so growth is deliberate
        assert_eq!(dw.arena_bytes(), 3 * total as u64 * 4);
    }

    #[test]
    fn chunked_broadcast_streams_the_exact_one_shot_bytes() {
        // multi-block leaves so the shard cutter actually cuts
        let layout = Arc::new(FlatLayout::new(vec![vec![700], vec![300, 2], vec![513]]));
        let total = layout.total();
        let init: Vec<f32> = (0..total).map(|i| (i as f32 * 0.01).cos()).collect();
        for bits in [OuterBits::Fp32, OuterBits::Int4] {
            for threads in [1, 3, 8] {
                let mk = || {
                    DownWire::new(
                        Channel::new(layout.clone(), codec_for(bits), 2, 13, Direction::Down),
                        &init,
                    )
                };
                let mut oracle = mk();
                let mut chunked = mk();
                // two syncs, so the second round exercises carried EF state
                for round in 0..2u64 {
                    let global: Vec<f32> = (0..total)
                        .map(|i| init[i] + ((i as u64 + round) as f32 * 0.03).sin())
                        .collect();
                    let mut one_shot = WireBuf::new();
                    oracle
                        .encode_broadcast_into(&global, Some(1), round, 1, &mut one_shot)
                        .unwrap();
                    let mut streamed = Vec::new();
                    let mut out = WireBuf::new();
                    let mut chunks = 0usize;
                    chunked
                        .encode_broadcast_chunked(
                            &global,
                            Some(1),
                            round,
                            threads,
                            &mut out,
                            &mut |c| {
                                chunks += 1;
                                streamed.extend_from_slice(c);
                                Ok(())
                            },
                        )
                        .unwrap();
                    assert!(chunks >= 1, "{bits:?} t={threads}");
                    // flushed chunks concatenate to the one-shot frame,
                    // and the retained buffer holds the same payload
                    assert_eq!(streamed, one_shot.payload(), "{bits:?} t={threads} r={round}");
                    assert_eq!(out.payload(), one_shot.payload());
                    // EF state advanced identically on both wires
                    for i in 0..total {
                        assert_eq!(
                            chunked.view()[i].to_bits(),
                            oracle.view()[i].to_bits(),
                            "{bits:?} t={threads} view[{i}]"
                        );
                        assert_eq!(
                            chunked.residual()[i].to_bits(),
                            oracle.residual()[i].to_bits(),
                            "{bits:?} t={threads} residual[{i}]"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn streamed_encode_flushes_the_exact_one_shot_bytes() {
        // multi-block leaves so the shard cutter actually cuts, with an
        // odd tail so int4's padded final block is exercised
        let layout = Arc::new(FlatLayout::new(vec![vec![700], vec![300, 2], vec![513]]));
        let total = layout.total();
        let delta: Vec<f32> = (0..total).map(|i| ((i as f32) * 0.37).sin()).collect();
        let resid0: Vec<f32> = (0..total).map(|i| (i as f32 * 0.001) - 0.9).collect();
        for bits in OuterBits::ALL {
            let c = Channel::new(layout.clone(), codec_for(bits), 2, 11, Direction::Up);
            let mut base_wire = WireBuf::new();
            let mut base_stage = delta.clone();
            let mut base_resid = resid0.clone();
            c.encode_ef_into(&mut base_stage, &mut base_resid, Some(1), 4, 2, 1, &mut base_wire)
                .unwrap();
            for chunks in [1, 2, 5, 16] {
                let mut wire = WireBuf::new();
                let mut stage = delta.clone();
                let mut resid = resid0.clone();
                let mut streamed = Vec::new();
                let mut offs = Vec::new();
                c.encode_ef_streamed(
                    &mut stage,
                    &mut resid,
                    Some(1),
                    4,
                    2,
                    chunks,
                    &mut wire,
                    &mut |off, bytes| {
                        offs.push((off, bytes.len()));
                        streamed.extend_from_slice(bytes);
                        Ok(())
                    },
                )
                .unwrap();
                // offsets are contiguous from 0 — the receive-side
                // watermark discipline depends on this
                let mut expect = 0usize;
                for &(off, len) in &offs {
                    assert_eq!(off, expect, "{bits:?} chunks={chunks}");
                    expect = off + len;
                }
                assert_eq!(expect, base_wire.payload_len());
                assert_eq!(streamed, base_wire.payload(), "{bits:?} chunks={chunks}");
                assert_eq!(wire.payload(), base_wire.payload());
                for i in 0..total {
                    assert_eq!(stage[i].to_bits(), base_stage[i].to_bits());
                    assert_eq!(resid[i].to_bits(), base_resid[i].to_bits());
                }
            }
        }
    }

    #[test]
    fn chunked_flush_failure_propagates() {
        let total = layout().total();
        let init = vec![0.0f32; total];
        let mut dw = DownWire::new(
            Channel::new(layout(), codec_for(OuterBits::Int8), 1, 7, Direction::Down),
            &init,
        );
        let global: Vec<f32> = (0..total).map(|i| i as f32).collect();
        let err = dw
            .encode_broadcast_chunked(
                &global,
                None,
                0,
                4,
                &mut WireBuf::new(),
                &mut |_| anyhow::bail!("socket died"),
            )
            .expect_err("flush failure must surface");
        assert!(format!("{err:#}").contains("socket died"));
    }
}
