//! Compute-utilization simulator — paper Table 6 / Figure 10.
//!
//! The paper uses Douillard et al. 2025's (unreleased) simulator to
//! report, for three LLM archetypes and a range of sync cadences H, the
//! minimum bandwidth needed to reach a given compute utilization
//! CU = compute_time / (compute_time + communication_time).
//!
//! Reverse-engineering notes (DESIGN.md section 5):
//! - Reported bandwidths lie exactly on the grid
//!   `logspace(0.1, 1000, 50)` Gbit/s (spacing 4/49 decades — e.g.
//!   104.8 = 10^(-1 + 37*4/49)); the simulator reports the smallest
//!   grid point whose CU meets the target, rounded to one decimal.
//! - Data-Parallel and DiLoCo(H=1) rows are identical, so only the
//!   cross-DC sync traffic is modeled (within-DC is free).
//! - Fitting the DP rows pins per-sync traffic ~ 8 bits/param for DP;
//!   DiLoCo rows consistently need ~1.5x that, i.e. reduce (2N·b/2)
//!   plus broadcast (N·b/2) of the updated params.
//! The remaining modeling constants are calibrated against the 90
//! published cells by `calibrate` (see EXPERIMENTS.md for the residual).

/// One LLM archetype row-block of Table 6.
#[derive(Debug, Clone)]
pub struct LlmArchetype {
    pub name: &'static str,
    pub params: f64,
    /// Idealized per-step compute time (paper: Kaplan FLOPs rule at
    /// 60% max FLOP utilization).
    pub step_time_s: f64,
}

pub const CHINCHILLA_10B: LlmArchetype = LlmArchetype {
    name: "Chinchilla-10B",
    params: 10e9,
    step_time_s: 0.8,
};
pub const LLAMA3_405B: LlmArchetype = LlmArchetype {
    name: "Llama3-405B",
    params: 405e9,
    step_time_s: 26.0,
};
pub const DEEPSEEK_671B: LlmArchetype = LlmArchetype {
    name: "DeepSeek-V3-671B",
    params: 671e9,
    step_time_s: 20.0,
};

pub const ARCHETYPES: [LlmArchetype; 3] = [CHINCHILLA_10B, LLAMA3_405B, DEEPSEEK_671B];

/// The paper's H column: Data-Parallel, then DiLoCo with these cadences.
pub const CADENCES: [usize; 5] = [1, 10, 50, 100, 300];

/// CU targets of Table 6's five columns.
pub const CU_TARGETS: [f64; 5] = [0.50, 0.80, 0.90, 0.95, 0.99];

/// Tunable modeling constants (defaults = calibrated values).
#[derive(Debug, Clone)]
pub struct SimModel {
    /// Per-sync cross-DC traffic for a Data-Parallel gradient
    /// all-reduce, in bits per parameter.
    pub dp_bits_per_param: f64,
    /// Ratio of DiLoCo outer-sync traffic to DP traffic
    /// (reduce + broadcast = 1.5x).
    pub outer_traffic_ratio: f64,
    /// Per-sync latency floor in seconds.
    pub latency_s: f64,
}

impl Default for SimModel {
    fn default() -> Self {
        // Calibrated against the paper's CU=50% column: DP per-sync
        // traffic = 8 bits/param; DiLoCo outer syncs carry ~1.375x that
        // (reduce + partial broadcast), EXCEPT H=1 which the paper
        // reports as identical to DP (see `sync_bits`). These constants
        // land every CU=50% cell within one bandwidth-grid step.
        SimModel {
            dp_bits_per_param: 8.0,
            outer_traffic_ratio: 1.375,
            latency_s: 0.0,
        }
    }
}

/// The bandwidth grid the paper reports on: logspace(0.1, 1000, 50) Gbit/s.
pub fn bandwidth_grid_gbps() -> Vec<f64> {
    (0..50)
        .map(|k| 10f64.powf(-1.0 + 4.0 * k as f64 / 49.0))
        .collect()
}

/// Round like the paper's table (one decimal).
pub fn round1(x: f64) -> f64 {
    (x * 10.0).round() / 10.0
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimAlgo {
    DataParallel,
    DiLoCo { sync_every: usize },
}

impl SimModel {
    /// Per-sync traffic in bits. DiLoCo H=1 degenerates to a per-step
    /// gradient all-reduce (the paper's Table 6 lists it identical to
    /// Data-Parallel), so the outer-traffic multiplier applies only for
    /// H > 1.
    fn sync_bits(&self, algo: SimAlgo, params: f64) -> f64 {
        let base = params * self.dp_bits_per_param;
        match algo {
            SimAlgo::DataParallel | SimAlgo::DiLoCo { sync_every: 1 } => base,
            SimAlgo::DiLoCo { .. } => base * self.outer_traffic_ratio,
        }
    }

    fn cadence(algo: SimAlgo) -> f64 {
        match algo {
            SimAlgo::DataParallel => 1.0,
            SimAlgo::DiLoCo { sync_every } => sync_every as f64,
        }
    }

    /// Compute utilization at a given cross-DC bandwidth.
    pub fn utilization(
        &self,
        arch: &LlmArchetype,
        algo: SimAlgo,
        bandwidth_gbps: f64,
    ) -> f64 {
        let h = Self::cadence(algo);
        let per_sync = self.sync_bits(algo, arch.params) / (bandwidth_gbps * 1e9)
            + self.latency_s;
        let compute = h * arch.step_time_s;
        compute / (compute + per_sync)
    }

    /// Smallest grid bandwidth reaching the CU target (Table 6 cell);
    /// None = above the grid ("1000.0+").
    pub fn required_bandwidth_gbps(
        &self,
        arch: &LlmArchetype,
        algo: SimAlgo,
        cu_target: f64,
    ) -> Option<f64> {
        bandwidth_grid_gbps()
            .into_iter()
            .find(|&w| self.utilization(arch, algo, w) >= cu_target)
            .map(round1)
    }

    /// Full Table 6 block for one archetype: rows = [DP, DiLoCo H in
    /// CADENCES[1..]], columns = CU_TARGETS. None cells are "1000.0+".
    pub fn table6_block(&self, arch: &LlmArchetype) -> Vec<(String, Vec<Option<f64>>)> {
        let mut rows = Vec::new();
        let mut algos: Vec<(String, SimAlgo)> =
            vec![("Data-Parallel".into(), SimAlgo::DataParallel)];
        for h in CADENCES {
            algos.push((format!("DiLoCo, H={h}"), SimAlgo::DiLoCo { sync_every: h }));
        }
        for (label, algo) in algos {
            let cells = CU_TARGETS
                .iter()
                .map(|&cu| self.required_bandwidth_gbps(arch, algo, cu))
                .collect();
            rows.push((label, cells));
        }
        rows
    }
}

/// Grid-search calibration of the modeling constants against the
/// paper's published Table 6 (report/paperdata.rs). Returns the model
/// with the most exactly-matching cells and the match count.
pub fn calibrate(
    published: &[(&'static str, usize, [Option<f64>; 5])],
) -> (SimModel, usize, usize) {
    let mut best = (SimModel::default(), 0usize);
    let mut total = 0usize;
    for &(_, _, cells) in published {
        total += cells.iter().filter(|c| c.is_some()).count();
    }
    for dp_bits in [4.0, 6.0, 8.0, 12.0, 16.0, 32.0] {
        for ratio in [1.0, 1.25, 1.375, 1.5, 2.0] {
            for latency in [0.0, 1e-3, 1e-2, 1e-1] {
                let m = SimModel {
                    dp_bits_per_param: dp_bits,
                    outer_traffic_ratio: ratio,
                    latency_s: latency,
                };
                let mut matches = 0usize;
                for &(arch_name, h, ref cells) in published {
                    let arch = ARCHETYPES
                        .iter()
                        .find(|a| a.name == arch_name)
                        .expect("archetype");
                    let algo = if h == 0 {
                        SimAlgo::DataParallel
                    } else {
                        SimAlgo::DiLoCo { sync_every: h }
                    };
                    for (i, cell) in cells.iter().enumerate() {
                        if let Some(want) = cell {
                            let got = m.required_bandwidth_gbps(arch, algo, CU_TARGETS[i]);
                            if got == Some(*want) {
                                matches += 1;
                            }
                        }
                    }
                }
                if matches > best.1 {
                    best = (m, matches);
                }
            }
        }
    }
    (best.0, best.1, total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_contains_published_values() {
        // Spot-check the reverse-engineered grid against values that
        // appear verbatim in Table 6. Tolerance is relative 0.3%: two
        // published cells (323.8, 569.0) sit 0.02-0.05% off the exact
        // logspace points (sub-rounding noise in the paper's table).
        let grid: Vec<f64> = bandwidth_grid_gbps();
        for v in [104.8, 184.2, 222.3, 390.7, 126.5, 268.3, 323.8, 569.0, 686.6, 16.0, 49.4, 86.8, 152.6, 1.4, 0.5, 3.0, 11.0, 23.3, 41.0, 6.2, 13.3, 9.1, 2.0, 4.3, 1.7, 7.5, 33.9, 72.0, 59.6, 28.1, 19.3, 3.6] {
            assert!(
                grid.iter().any(|&g| (g / v - 1.0).abs() < 3e-3 || (g - v).abs() < 0.06),
                "{v} not on grid"
            );
        }
    }

    #[test]
    fn cu_monotone_in_bandwidth_and_h() {
        let m = SimModel::default();
        let arch = &CHINCHILLA_10B;
        let mut prev = 0.0;
        for w in bandwidth_grid_gbps() {
            let cu = m.utilization(arch, SimAlgo::DataParallel, w);
            assert!(cu >= prev);
            prev = cu;
        }
        let w = 10.0;
        let mut prev = 0.0;
        for h in [1usize, 10, 50, 100, 300] {
            let cu = m.utilization(arch, SimAlgo::DiLoCo { sync_every: h }, w);
            assert!(cu > prev, "H={h}");
            prev = cu;
        }
    }

    #[test]
    fn dp_matches_diloco_h1_modulo_traffic_ratio() {
        // With ratio=1.0 DP and DiLoCo H=1 are identical (the paper's
        // table shows identical rows).
        let m = SimModel {
            outer_traffic_ratio: 1.0,
            ..SimModel::default()
        };
        let arch = &LLAMA3_405B;
        for cu in CU_TARGETS {
            assert_eq!(
                m.required_bandwidth_gbps(arch, SimAlgo::DataParallel, cu),
                m.required_bandwidth_gbps(arch, SimAlgo::DiLoCo { sync_every: 1 }, cu)
            );
        }
    }

    #[test]
    fn headline_dp_cell_matches_paper() {
        // Table 6: Chinchilla-10B, Data-Parallel, CU=50% -> 104.8 Gbit/s.
        let m = SimModel::default();
        let got = m.required_bandwidth_gbps(&CHINCHILLA_10B, SimAlgo::DataParallel, 0.5);
        assert_eq!(got, Some(104.8));
    }

    #[test]
    fn bandwidth_reduction_is_orders_of_magnitude() {
        // The paper's headline: DiLoCo H=300 needs >100x less bandwidth
        // than DP at CU=50%.
        let m = SimModel::default();
        let dp = m
            .required_bandwidth_gbps(&CHINCHILLA_10B, SimAlgo::DataParallel, 0.5)
            .unwrap();
        let dl = m
            .required_bandwidth_gbps(
                &CHINCHILLA_10B,
                SimAlgo::DiLoCo { sync_every: 300 },
                0.5,
            )
            .unwrap();
        assert!(dp / dl > 100.0, "reduction only {}", dp / dl);
    }
}
