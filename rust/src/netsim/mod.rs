//! Analytic systems models from the paper:
//! - `walltime`: the idealized end-to-end wall-clock model (Appendix A),
//! - `utilization`: the compute-utilization/bandwidth simulator behind
//!   Table 6 / Figure 10 (Douillard et al. 2025's simulator,
//!   reverse-engineered and calibrated — DESIGN.md section 5).

pub mod utilization;
pub mod walltime;

/// A network archetype (Appendix A.3): bandwidth in bits/s, latency in
/// seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Network {
    pub name: &'static str,
    pub bandwidth_bps: f64,
    pub latency_s: f64,
}

/// The paper's three cross-datacenter archetypes.
pub const HIGH: Network = Network {
    name: "high",
    bandwidth_bps: 400e9,
    latency_s: 1e-4,
};
pub const MEDIUM: Network = Network {
    name: "medium",
    bandwidth_bps: 100e9,
    latency_s: 1e-3,
};
pub const LOW: Network = Network {
    name: "low",
    bandwidth_bps: 10e9,
    latency_s: 1e-2,
};

pub const ARCHETYPES: [Network; 3] = [LOW, MEDIUM, HIGH];

/// Within-datacenter network is always the high-bandwidth archetype.
pub const WITHIN_DC: Network = HIGH;

/// Bandwidth-optimal all-reduce time over R nodes (Patarasuk & Yuan):
/// traffic per node >= 2*size*(1-1/R); plus one latency term.
pub fn allreduce_time(size_bits: f64, r: f64, net: Network) -> f64 {
    if r <= 1.0 {
        return 0.0;
    }
    2.0 * size_bits / net.bandwidth_bps * (1.0 - 1.0 / r) + net.latency_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_zero_for_single_node() {
        assert_eq!(allreduce_time(1e9, 1.0, HIGH), 0.0);
    }

    #[test]
    fn allreduce_scales_with_size_and_bandwidth() {
        let t_small = allreduce_time(1e9, 8.0, HIGH);
        let t_big = allreduce_time(2e9, 8.0, HIGH);
        assert!(t_big > t_small);
        let t_slow = allreduce_time(1e9, 8.0, LOW);
        assert!(t_slow > t_small);
    }

    #[test]
    fn allreduce_approaches_2n_over_w() {
        let t = allreduce_time(400e9, 1e9, HIGH); // huge R
        assert!((t - (2.0 + HIGH.latency_s)).abs() < 1e-6);
    }
}
