//! Idealized wall-clock time model — paper Appendix A, implemented
//! exactly (Figures 6 and 12 are generated from this).
//!
//! Computation: total FLOPs C = 6*N*D over R chips at Q FLOP/s each,
//! where R scales linearly with the global batch (doubling batch
//! doubles chips, halving serial steps). Communication: bandwidth-
//! optimal all-reduces; Data-Parallel all-reduces over the
//! cross-datacenter network every step, DiLoCo(M>=2) all-reduces
//! within-datacenter every step and cross-datacenter every H steps;
//! DiLoCo(M=1) behaves like Data-Parallel plus the outer step every H.
//!
//! **Overlap term** (Streaming DiLoCo's delayed application,
//! `--overlap-tau`): the H-cadence outer sync no longer stops the
//! workers — its communication runs under τ inner steps of compute,
//! so each sync's effective serial cost is
//! `max(0, t_outer − τ·t_step)` where `t_step` is the per-step
//! compute time. At τ=0 this collapses exactly to the paper's serial
//! bubble; at `τ·t_step ≥ t_outer` the outer leg vanishes from the
//! critical path entirely (the paper's Appendix-A aspiration, and
//! the `stream` sweep grid's subject). Per-step gradient traffic is
//! never overlapped — only the H-cadence outer legs are.

use super::{allreduce_time, Network, WITHIN_DC};

/// Q = 300 TFLOP/s per chip (paper: between TPU v5e's ~100 and v6e's
/// ~408 effective bf16 TFLOP/s at 50% MFU).
pub const CHIP_FLOPS: f64 = 300e12;

/// Tokens each chip processes per step; fixes R = batch_tokens / this.
/// The paper uses "a slightly idealized number of chips based on our
/// experiments, ensuring doubling the global batch doubles R".
pub const TOKENS_PER_CHIP: f64 = 16_384.0;

/// bf16 weights/gradients (paper section 3): the per-step gradient
/// exchange width, and the default outer width when a run does not
/// compress its outer communication.
pub const BITS_PER_PARAM: f64 = 16.0;

#[derive(Debug, Clone, Copy)]
pub enum WalltimeAlgo {
    DataParallel,
    DiLoCo { replicas: usize, sync_every: usize },
}

/// Replica churn, as it reaches the wall-clock model (the loss cost of
/// churn is measured by real runs — `sweep --grid churn`; this is only
/// the systems side). Two effects on the H-cadence outer legs:
///
/// - **Dropout**: a crashed/departed replica contributes nothing to
///   the reduce, so the expected up-leg volume shrinks by the dropout
///   rate. Dropout can only *cheapen* the outer sync — the coordinator
///   means over survivors and never waits for the dead (the drive
///   loop's membership semantics), so there is no timeout term.
/// - **Stragglers**: a fraction of syncs arrive late, stretching that
///   sync's outer leg by a slowdown factor **before** the τ-window
///   hiding applies — a straggling sync needs proportionally more
///   compute to hide under, exactly how `--overlap-tau` interacts
///   with slow links.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnModel {
    /// Fraction of replica-sync contributions lost to crashes/leaves
    /// (`FaultPlan::dropout_rate`), in [0, 1].
    pub dropout_rate: f64,
    /// Fraction of outer syncs slowed by a straggling replica, in [0, 1].
    pub straggler_frac: f64,
    /// Outer-leg time multiplier for a straggling sync (>= 1).
    pub straggler_slowdown: f64,
}

#[derive(Debug, Clone)]
pub struct WalltimeInput {
    pub algo: WalltimeAlgo,
    /// Model parameters N.
    pub params: f64,
    /// Token budget D.
    pub tokens: f64,
    /// Global batch size in tokens.
    pub batch_tokens: f64,
    /// Cross-datacenter network (within-DC is always HIGH).
    pub cross_dc: Network,
    /// Bits per parameter on the **up leg** of the outer sync (the
    /// H-cadence reduce of replica contributions — the reduce-scatter
    /// half of a bandwidth-optimal all-reduce). [`BITS_PER_PARAM`]
    /// (bf16) for uncompressed runs; a run's `--outer-bits` width
    /// (32/16/8/4) otherwise — the comm subsystem's quantized outer
    /// gradients shrink exactly this term. Per-step gradient traffic
    /// (DP's cross-DC all-reduce, DiLoCo's within-DC all-reduce)
    /// stays at bf16, matching the paper's section-3 setup.
    pub outer_bits: f64,
    /// Bits per parameter on the **down leg** (the broadcast of the
    /// refreshed global — the all-gather half). [`BITS_PER_PARAM`]
    /// for uncompressed runs; a run's `--outer-bits-down` width
    /// otherwise. With both legs equal the outer term collapses to
    /// the classic symmetric all-reduce.
    pub outer_bits_down: f64,
    /// Delayed-application window τ in inner steps (`--overlap-tau`):
    /// each outer sync's communication is hidden under τ steps of
    /// compute, charging `max(0, t_outer − τ·t_step)` per sync. 0 =
    /// the paper's serial bubble, exactly. Data-Parallel ignores it
    /// (no outer sync exists).
    pub overlap_tau: f64,
    /// Replica churn scenario ([`ChurnModel`]); `None` is bit-identical
    /// to the churn-free model. Data-Parallel ignores it (no outer
    /// sync to drop out of or straggle on).
    pub churn: Option<ChurnModel>,
}

/// One H-cadence outer sync over `r` nodes: the reduce leg at the up
/// width plus the broadcast leg at the down width. Each leg moves
/// `size*(1 - 1/r)` bits per node in the bandwidth-optimal schedule
/// (Patarasuk & Yuan), so with `bits_up == bits_down` this is exactly
/// [`crate::netsim::allreduce_time`].
pub fn outer_sync_time(
    bits_up: f64,
    bits_down: f64,
    r: f64,
    net: crate::netsim::Network,
) -> f64 {
    if r <= 1.0 {
        return 0.0;
    }
    (bits_up + bits_down) / net.bandwidth_bps * (1.0 - 1.0 / r) + net.latency_s
}

/// Calibration bridge between **measured** wire traffic and the
/// Appendix-A model: convert a run's exact framed byte total
/// (`RunMetrics::wire_framed_bytes` — encoded payloads plus one
/// transport frame header per contribution and per broadcast, what
/// the TCP transport actually writes to a socket) into model seconds
/// on a network archetype, charging one latency per outer sync. The
/// analytic `walltime()` above models ideal all-reduces over chips;
/// this models the repo's real star topology (M replicas → one
/// coordinator), so comparing the two against a measured loopback or
/// LAN run separates model error from transport overhead — see
/// EXPERIMENTS.md "Socket calibration".
pub fn measured_comm_time(framed_bytes: u64, outer_syncs: usize, net: Network) -> f64 {
    framed_bytes as f64 * 8.0 / net.bandwidth_bps + outer_syncs as f64 * net.latency_s
}

#[derive(Debug, Clone)]
pub struct WalltimeBreakdown {
    pub steps: f64,
    pub chips: f64,
    pub compute_s: f64,
    pub comm_s: f64,
}

impl WalltimeBreakdown {
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.comm_s
    }
}

/// Ideal speedup of the coordinator's replica-parallel inner loop:
/// with M equal-cost replica inner loops spread over W persistent
/// workers (replica r on worker r % W), each segment's serial depth is
/// ceil(M/W) inner loops, so the speedup over sequential execution
/// (W=1) is M / ceil(M/W). This is the measured-concurrency analogue
/// of Appendix A's assumption that the M replicas compute
/// independently between outer syncs; `benches/bench_hot_path.rs`
/// records measured pool wall-clock against this model for
/// M in {1, 2, 4, 8}. (Host-side outer-step cost is excluded — it is
/// the barrier, identical in both modes.)
pub fn replica_parallel_speedup(replicas: usize, workers: usize) -> f64 {
    let m = replicas.max(1);
    let w = workers.clamp(1, m);
    let depth = (m + w - 1) / w;
    m as f64 / depth as f64
}

/// Appendix A.3: total wall-clock = computation + communication.
pub fn walltime(input: &WalltimeInput) -> WalltimeBreakdown {
    let steps = (input.tokens / input.batch_tokens).ceil();
    let chips = (input.batch_tokens / TOKENS_PER_CHIP).max(1.0);
    let compute = 6.0 * input.params * input.tokens / (chips * CHIP_FLOPS);
    // per-step gradient exchange is always bf16; the H-cadence outer
    // sync moves outer gradients up at the run's up-wire width and the
    // broadcast back down at its down-wire width
    let bits = input.params * BITS_PER_PARAM;
    let bits_up = input.params * input.outer_bits;
    let bits_down = input.params * input.outer_bits_down;
    // the overlap window hides τ steps of compute worth of outer-leg
    // communication per sync (delayed application); τ=0 degenerates to
    // the paper's serial bubble, term for term
    let t_step = if steps > 0.0 { compute / steps } else { 0.0 };
    let overlapped_outer = |sync_every: usize| -> f64 {
        // churn reshapes the outer leg only: dropout thins the up-leg
        // volume (survivor-mean, no waiting on the dead), stragglers
        // stretch the sync before the τ window hides any of it
        let (up_eff, straggle) = match &input.churn {
            Some(c) => (
                bits_up * (1.0 - c.dropout_rate.clamp(0.0, 1.0)),
                1.0 + c.straggler_frac.clamp(0.0, 1.0)
                    * (c.straggler_slowdown.max(1.0) - 1.0),
            ),
            None => (bits_up, 1.0),
        };
        let per_sync = outer_sync_time(up_eff, bits_down, chips, input.cross_dc) * straggle;
        let hidden = input.overlap_tau.max(0.0) * t_step;
        (per_sync - hidden).max(0.0) * steps / sync_every as f64
    };
    let comm = match input.algo {
        WalltimeAlgo::DataParallel => {
            // all-reduce over all R chips across DCs, every step
            allreduce_time(bits, chips, input.cross_dc) * steps
        }
        WalltimeAlgo::DiLoCo {
            replicas: 1,
            sync_every,
        } => {
            // per-step all-reduce like DP, plus outer sync every H
            allreduce_time(bits, chips, input.cross_dc) * steps
                + overlapped_outer(sync_every)
        }
        WalltimeAlgo::DiLoCo {
            replicas,
            sync_every,
        } => {
            let m = replicas as f64;
            // inner: R/M chips within one DC, every step (the (1-M/R)
            // factor from Appendix A.2)
            let inner = (2.0 * bits / WITHIN_DC.bandwidth_bps * (1.0 - m / chips).max(0.0)
                + WITHIN_DC.latency_s)
                * steps;
            // outer: all R chips across DCs, every H steps, minus the
            // τ-step compute window it hides under
            inner + overlapped_outer(sync_every)
        }
    };
    WalltimeBreakdown {
        steps,
        chips,
        compute_s: compute,
        comm_s: comm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::{HIGH, LOW, MEDIUM};

    fn base(algo: WalltimeAlgo, net: Network) -> WalltimeInput {
        WalltimeInput {
            algo,
            params: 1e9,
            tokens: 20e9,
            batch_tokens: 2f64.powi(20),
            cross_dc: net,
            outer_bits: BITS_PER_PARAM,
            outer_bits_down: BITS_PER_PARAM,
            overlap_tau: 0.0,
            churn: None,
        }
    }

    #[test]
    fn compute_time_is_budget_over_chips() {
        let w = walltime(&base(WalltimeAlgo::DataParallel, HIGH));
        let expect = 6.0 * 1e9 * 20e9 / (w.chips * CHIP_FLOPS);
        assert!((w.compute_s - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn diloco_beats_dp_on_low_bandwidth() {
        // Finding in Fig 6: DiLoCo's reduced cross-DC chatter wins,
        // dramatically so on the low-bandwidth archetype.
        let dp = walltime(&base(WalltimeAlgo::DataParallel, LOW));
        let dl = walltime(&base(
            WalltimeAlgo::DiLoCo {
                replicas: 4,
                sync_every: 30,
            },
            LOW,
        ));
        assert!(dl.total_s() < dp.total_s() * 0.5, "{} vs {}", dl.total_s(), dp.total_s());
    }

    #[test]
    fn diloco_m1_slightly_worse_comm_than_dp() {
        // M=1 pays the outer sync on top of per-step all-reduce: the
        // (1 + 1/H) factor of Appendix A.2.
        let dp = walltime(&base(WalltimeAlgo::DataParallel, MEDIUM));
        let m1 = walltime(&base(
            WalltimeAlgo::DiLoCo {
                replicas: 1,
                sync_every: 30,
            },
            MEDIUM,
        ));
        let ratio = m1.comm_s / dp.comm_s;
        assert!((ratio - (1.0 + 1.0 / 30.0)).abs() < 1e-9);
    }

    #[test]
    fn larger_batch_reduces_walltime_for_diloco() {
        // Finding 3 consequence: horizontal scaling. More chips => less
        // serial compute; DiLoCo comm doesn't blow up with batch.
        let mut a = base(
            WalltimeAlgo::DiLoCo {
                replicas: 2,
                sync_every: 30,
            },
            MEDIUM,
        );
        let t1 = walltime(&a).total_s();
        a.batch_tokens *= 4.0;
        let t2 = walltime(&a).total_s();
        assert!(t2 < t1);
    }

    #[test]
    fn replica_parallel_speedup_model() {
        // full parallelism: W = M gives exactly M
        for m in [1usize, 2, 4, 8] {
            assert_eq!(replica_parallel_speedup(m, m), m as f64);
        }
        // sequential: always 1
        assert_eq!(replica_parallel_speedup(8, 1), 1.0);
        // partial: serial depth is ceil(M/W)
        assert_eq!(replica_parallel_speedup(4, 2), 2.0);
        assert_eq!(replica_parallel_speedup(4, 3), 2.0); // depth ceil(4/3)=2
        assert_eq!(replica_parallel_speedup(8, 3), 8.0 / 3.0);
        // workers beyond M are clamped; degenerate inputs saturate at 1
        assert_eq!(replica_parallel_speedup(2, 16), 2.0);
        assert_eq!(replica_parallel_speedup(0, 0), 1.0);
        // never exceeds M, never below 1
        for m in 1..12usize {
            for w in 1..12usize {
                let s = replica_parallel_speedup(m, w);
                assert!((1.0..=m as f64).contains(&s), "M={m} W={w}: {s}");
            }
        }
    }

    #[test]
    fn reduced_outer_bits_shrink_only_the_outer_term() {
        // 4-bit wires on both legs (paper section 7 / the comm
        // subsystem) cut the H-cadence cross-DC term ~4x vs bf16;
        // per-step inner traffic is untouched, and DP ignores both
        // knobs entirely.
        let algo = WalltimeAlgo::DiLoCo {
            replicas: 4,
            sync_every: 30,
        };
        let mut a = base(algo, LOW);
        let bf16 = walltime(&a);
        a.outer_bits = 4.0;
        a.outer_bits_down = 4.0;
        let int4 = walltime(&a);
        assert!(int4.comm_s < bf16.comm_s, "{} vs {}", int4.comm_s, bf16.comm_s);
        // isolate the outer term via an H -> inf run (inner only)
        let mut inf = base(algo, LOW);
        if let WalltimeAlgo::DiLoCo { sync_every, .. } = &mut inf.algo {
            *sync_every = usize::MAX;
        }
        let inner_only = walltime(&inf).comm_s;
        let outer_bf16 = bf16.comm_s - inner_only;
        let outer_int4 = int4.comm_s - inner_only;
        // bandwidth term scales exactly 4x; latency terms dilute it a bit
        assert!(outer_int4 < outer_bf16 / 3.0, "{outer_int4} vs {outer_bf16}");
        assert!(outer_int4 > outer_bf16 / 16.0);
        // DP: neither knob is relevant (no outer sync exists)
        let mut dp = base(WalltimeAlgo::DataParallel, LOW);
        let t16 = walltime(&dp).comm_s;
        dp.outer_bits = 4.0;
        dp.outer_bits_down = 4.0;
        assert_eq!(walltime(&dp).comm_s, t16);
        // compute time never depends on the wire widths
        assert_eq!(bf16.compute_s, int4.compute_s);
    }

    #[test]
    fn down_leg_is_half_the_symmetric_outer_term() {
        // Narrowing only the broadcast halves at most half the outer
        // term: the up leg still ships bf16. The split model collapses
        // to the classic all-reduce when both legs match.
        let algo = WalltimeAlgo::DiLoCo {
            replicas: 4,
            sync_every: 30,
        };
        let mut inf = base(algo, LOW);
        if let WalltimeAlgo::DiLoCo { sync_every, .. } = &mut inf.algo {
            *sync_every = usize::MAX;
        }
        let inner_only = walltime(&inf).comm_s;
        let outer_of = |up: f64, down: f64| {
            let mut i = base(algo, LOW);
            i.outer_bits = up;
            i.outer_bits_down = down;
            walltime(&i).comm_s - inner_only
        };
        let symmetric = outer_of(BITS_PER_PARAM, BITS_PER_PARAM);
        // both-legs-equal == the pre-split allreduce_time model
        let chips = walltime(&base(algo, LOW)).chips;
        let classic = crate::netsim::allreduce_time(1e9 * BITS_PER_PARAM, chips, LOW)
            * walltime(&base(algo, LOW)).steps
            / 30.0;
        assert!((symmetric - classic).abs() / classic < 1e-9);
        // down-only narrowing lands strictly between half and full
        let down4 = outer_of(BITS_PER_PARAM, 4.0);
        assert!(down4 < symmetric && down4 > symmetric / 2.0, "{down4} vs {symmetric}");
        // narrowing both beats narrowing either alone
        let up4 = outer_of(4.0, BITS_PER_PARAM);
        let both4 = outer_of(4.0, 4.0);
        assert!(both4 < down4 && both4 < up4);
        // the two single-leg narrows are symmetric in the model
        assert!((down4 - up4).abs() / down4 < 1e-9);
    }

    #[test]
    fn overlap_tau_strictly_shrinks_only_the_outer_term() {
        for m in [1usize, 4] {
            let algo = WalltimeAlgo::DiLoCo {
                replicas: m,
                sync_every: 30,
            };
            // τ=0 must be the exact pre-overlap formula (same floats)
            let barrier = walltime(&base(algo, LOW));
            let mut zero = base(algo, LOW);
            zero.overlap_tau = 0.0;
            assert_eq!(walltime(&zero).comm_s, barrier.comm_s, "M={m}");
            // any τ>0 strictly shrinks comm while t_comm > 0, and
            // compute is untouched
            let mut prev = barrier.comm_s;
            for tau in [1.0, 4.0, 16.0] {
                let mut i = base(algo, LOW);
                i.overlap_tau = tau;
                let w = walltime(&i);
                assert!(w.comm_s < prev, "M={m} tau={tau}: {} !< {prev}", w.comm_s);
                assert_eq!(w.compute_s, barrier.compute_s);
                prev = w.comm_s;
            }
            // a huge window floors the outer term at zero: comm equals
            // the inner-only (H -> inf) schedule, never goes negative
            let mut inf = base(algo, LOW);
            if let WalltimeAlgo::DiLoCo { sync_every, .. } = &mut inf.algo {
                *sync_every = usize::MAX;
            }
            let inner_only = walltime(&inf).comm_s;
            let mut deep = base(algo, LOW);
            deep.overlap_tau = 1e9;
            let hidden = walltime(&deep).comm_s;
            assert!((hidden - inner_only).abs() <= inner_only * 1e-12 + 1e-15, "M={m}");
        }
        // DP has no outer sync: τ is inert there
        let mut dp = base(WalltimeAlgo::DataParallel, LOW);
        let t0 = walltime(&dp).comm_s;
        dp.overlap_tau = 8.0;
        assert_eq!(walltime(&dp).comm_s, t0);
    }

    #[test]
    fn churn_reshapes_only_the_outer_leg() {
        let algo = WalltimeAlgo::DiLoCo {
            replicas: 4,
            sync_every: 30,
        };
        let clean = walltime(&base(algo, LOW));
        // an explicit zero-churn model is bit-identical to None
        let mut zero = base(algo, LOW);
        zero.churn = Some(ChurnModel {
            dropout_rate: 0.0,
            straggler_frac: 0.0,
            straggler_slowdown: 1.0,
        });
        assert_eq!(walltime(&zero).comm_s, clean.comm_s);
        assert_eq!(walltime(&zero).compute_s, clean.compute_s);
        // stragglers strictly stretch comm (compute untouched)...
        let mut slow = base(algo, LOW);
        slow.churn = Some(ChurnModel {
            dropout_rate: 0.0,
            straggler_frac: 0.25,
            straggler_slowdown: 4.0,
        });
        let w_slow = walltime(&slow);
        assert!(w_slow.comm_s > clean.comm_s, "{} !> {}", w_slow.comm_s, clean.comm_s);
        assert_eq!(w_slow.compute_s, clean.compute_s);
        // ...and a deep τ window still hides the stretched sync
        let mut hidden = slow.clone();
        hidden.overlap_tau = 1e9;
        let mut inf = base(algo, LOW);
        if let WalltimeAlgo::DiLoCo { sync_every, .. } = &mut inf.algo {
            *sync_every = usize::MAX;
        }
        let inner_only = walltime(&inf).comm_s;
        assert!((walltime(&hidden).comm_s - inner_only).abs() <= inner_only * 1e-12 + 1e-15);
        // dropout never increases walltime: the coordinator means over
        // survivors and never waits for the dead
        for d in [0.0, 0.05, 0.2, 0.5, 1.0] {
            let mut drop = base(algo, LOW);
            drop.churn = Some(ChurnModel {
                dropout_rate: d,
                straggler_frac: 0.0,
                straggler_slowdown: 1.0,
            });
            assert!(
                walltime(&drop).comm_s <= clean.comm_s,
                "dropout {d} increased comm"
            );
        }
        // DP has no outer sync: churn is inert there
        let mut dp = base(WalltimeAlgo::DataParallel, LOW);
        let t0 = walltime(&dp).comm_s;
        dp.churn = Some(ChurnModel {
            dropout_rate: 0.3,
            straggler_frac: 0.5,
            straggler_slowdown: 8.0,
        });
        assert_eq!(walltime(&dp).comm_s, t0);
    }

    #[test]
    fn measured_comm_time_is_bits_over_bandwidth_plus_latency() {
        let t = measured_comm_time(0, 0, LOW);
        assert_eq!(t, 0.0);
        // pure bandwidth term: 1 GiB over the LOW archetype
        let bytes = 1u64 << 30;
        let t = measured_comm_time(bytes, 0, LOW);
        assert!((t - bytes as f64 * 8.0 / LOW.bandwidth_bps).abs() < 1e-12);
        // each sync charges exactly one latency
        let t10 = measured_comm_time(bytes, 10, LOW);
        assert!((t10 - t - 10.0 * LOW.latency_s).abs() < 1e-12);
        // more traffic, more time — monotone in both arguments
        assert!(measured_comm_time(2 * bytes, 10, LOW) > t10);
    }

    #[test]
    fn h_controls_outer_comm_share() {
        // As long as H >= W0/W1 the outer steps cost at most half the
        // total communication (Appendix A.2 remark).
        let m = 4usize;
        let net = MEDIUM; // W0/W1 = 400/100 = 4
        // (H=4 = exactly W0/W1 sits right at the boundary and tips just
        // over 0.5 due to the latency terms, so start above it.)
        for h in [8usize, 30, 100] {
            let w = walltime(&base(
                WalltimeAlgo::DiLoCo {
                    replicas: m,
                    sync_every: h,
                },
                net,
            ));
            let inner_only = walltime(&base(
                WalltimeAlgo::DiLoCo {
                    replicas: m,
                    sync_every: usize::MAX,
                },
                net,
            ));
            let outer_share = (w.comm_s - inner_only.comm_s) / w.comm_s;
            assert!(outer_share <= 0.5 + 0.02, "H={h}: share {outer_share}");
        }
    }
}
