//! Named sweep grids — the mini-scale analogue of the paper's sweeps
//! (section 3.1), sized for this single-core substrate (DESIGN.md §3).
//!
//! Conventions carried over from the paper:
//! - inner LR swept in powers of sqrt(2) around a per-size center,
//! - (global) batch size swept in powers of 2 (sequences),
//! - outer LR in {0.2, 0.4, 0.6, 0.8, 1.0}, larger for larger M
//!   (Finding 4: optimal eta depends on M, not N),
//! - token budget fixed at Chinchilla 20N per run.
//!
//! Priority order matters: the runner executes grids front-to-back and
//! stores are resumable, so the most load-bearing data (loss ladder for
//! Table 4 / Fig 2) lands first.

use anyhow::{bail, Result};

use crate::comm::OuterBits;
use crate::coordinator::{Algo, RunConfig};

const SQRT2: f64 = std::f64::consts::SQRT_2;

/// Per-model LR grid center. Tiny models tolerate larger LRs; centers
/// were located with short pilot runs.
fn lr_center(model: &str) -> f64 {
    match model {
        "m0" => 1.7e-2,
        "m1" => 9.0e-3,
        "m2" => 5.0e-3,
        "m3" => 2.8e-3,
        "m4" => 1.6e-3,
        _ => 6.0e-3,
    }
}

fn lrs(center: f64, half_steps: &[i32]) -> Vec<f64> {
    half_steps.iter().map(|&k| center * SQRT2.powi(k)).collect()
}

/// Default outer-LR pair per replica count (bracketing the paper's
/// Finding 4 optima: eta grows with M).
fn etas_for(m: usize) -> Vec<f64> {
    match m {
        1 => vec![0.4, 0.8],
        2 => vec![0.6, 1.0],
        4 => vec![0.6, 1.0],
        _ => vec![0.8, 1.0],
    }
}

fn base(model: &str) -> RunConfig {
    RunConfig {
        model: model.into(),
        eval_tokens: 16 * 1024,
        log_every: 1000,
        ..Default::default()
    }
}

fn push(
    out: &mut Vec<RunConfig>,
    model: &str,
    algo: Algo,
    b: usize,
    lr: f64,
    eta: f64,
    f: impl Fn(&mut RunConfig),
) {
    let mut cfg = base(model);
    cfg.algo = algo;
    cfg.global_batch_seqs = b;
    cfg.inner_lr = lr;
    cfg.outer_lr = eta;
    f(&mut cfg);
    out.push(cfg);
}

/// Main loss-ladder sweep for one rung: the data behind Table 4 /
/// Figures 2, 4, 7 and the hyperparameter scaling laws (Tables 7-10).
fn main_grid(model: &str, budget_tier: usize) -> Vec<RunConfig> {
    let mut out = Vec::new();
    let c = lr_center(model);
    match budget_tier {
        // full grid (smallest rung)
        0 => {
            for lr in lrs(c, &[-2, 0, 2]) {
                for b in [8usize, 16, 32] {
                    push(&mut out, model, Algo::DataParallel, b, lr, 0.0, |cf| {
                        cf.downstream = true;
                    });
                }
            }
            for m in [1usize, 2, 4, 8] {
                for lr in lrs(c, &[-2, 0]) {
                    for b in [8usize, 16, 32] {
                        if b / m == 0 || b % m != 0 {
                            continue;
                        }
                        for eta in etas_for(m) {
                            push(
                                &mut out,
                                model,
                                Algo::DiLoCo { replicas: m },
                                b,
                                lr,
                                eta,
                                |cf| cf.downstream = true,
                            );
                        }
                    }
                }
            }
        }
        // reduced grid (middle rungs)
        1 => {
            for lr in lrs(c, &[0, 2]) {
                for b in [16usize, 32] {
                    push(&mut out, model, Algo::DataParallel, b, lr, 0.0, |cf| {
                        cf.downstream = true;
                    });
                }
            }
            for m in [1usize, 2, 4, 8] {
                for b in [16usize, 32] {
                    if b % m != 0 {
                        continue;
                    }
                    let eta = etas_for(m)[1];
                    push(
                        &mut out,
                        model,
                        Algo::DiLoCo { replicas: m },
                        b,
                        lr_center(model),
                        eta,
                        |cf| cf.downstream = true,
                    );
                }
            }
        }
        // minimal grid (top interpolation rung): one well-centred config
        // per algorithm (the paper's own protocol for its largest rungs:
        // no extensive tuning, hypers centred by the smaller-rung laws).
        _ => {
            push(&mut out, model, Algo::DataParallel, 16, c, 0.0, |cf| {
                cf.downstream = true;
            });
            for m in [1usize, 2, 4, 8] {
                let eta = etas_for(m)[1];
                push(
                    &mut out,
                    model,
                    Algo::DiLoCo { replicas: m },
                    16,
                    c,
                    eta,
                    |cf| cf.downstream = true,
                );
            }
        }
    }
    out
}

/// Synchronization-cadence ablation (Figures 8-9, section 5.1):
/// H in {1,5,10,30,100,300} at best-known hypers, plus an eta sweep at
/// three representative H values.
fn h_sweep(model: &str) -> Vec<RunConfig> {
    let mut out = Vec::new();
    let c = lr_center(model);
    for m in [1usize, 2, 4] {
        for h in [1usize, 5, 10, 30, 100, 300] {
            push(
                &mut out,
                model,
                Algo::DiLoCo { replicas: m },
                16,
                c,
                etas_for(m)[1],
                |cf| cf.sync_every = h,
            );
        }
    }
    for m in [1usize, 4] {
        for h in [1usize, 30, 300] {
            for eta in [0.2, 0.6] {
                push(
                    &mut out,
                    model,
                    Algo::DiLoCo { replicas: m },
                    16,
                    c,
                    eta,
                    |cf| cf.sync_every = h,
                );
            }
        }
    }
    out
}

/// Batch-size robustness (Figures 3-5, 14-19): extend the batch axis to
/// 64 and 128 sequences for DP and DiLoCo M in {1,2}.
fn batch_sweep(model: &str) -> Vec<RunConfig> {
    let mut out = Vec::new();
    let c = lr_center(model);
    for b in [64usize, 128] {
        push(&mut out, model, Algo::DataParallel, b, c, 0.0, |cf| {
            cf.downstream = true;
        });
        for m in [1usize, 2] {
            push(
                &mut out,
                model,
                Algo::DiLoCo { replicas: m },
                b,
                c,
                etas_for(m)[1],
                |cf| cf.downstream = true,
            );
        }
    }
    out
}

/// Overtraining ablation (Figure 11-12, section 5.2): overtrain
/// multipliers on the smallest rung with best-known hypers, no re-tune
/// (exactly the paper's protocol).
fn overtrain_sweep(model: &str) -> Vec<RunConfig> {
    let mut out = Vec::new();
    let c = lr_center(model);
    for ot in [1.0f64, 2.0, 4.0] {
        push(&mut out, model, Algo::DataParallel, 16, c, 0.0, |cf| {
            cf.overtrain = ot;
            // overtraining runs use a distinct seed (paper: Dolma, not C4)
            cf.seed = 1817;
        });
        for m in [1usize, 2] {
            push(
                &mut out,
                model,
                Algo::DiLoCo { replicas: m },
                16,
                c,
                etas_for(m)[1],
                |cf| {
                    cf.overtrain = ot;
                    cf.seed = 1817;
                },
            );
        }
    }
    out
}

/// The (up, down) wire-width pairs the `comm` grid covers, baseline
/// first: the symmetric ladder narrows both legs together, then the
/// two asymmetric int4 corners narrow one leg at a time so each
/// direction's loss cost is attributable on its own. This constant is
/// the single source of truth — `report::tables::table_comm` derives
/// its row set (and its baseline-anchor search) from it, so extending
/// the grid automatically extends the report.
pub const COMM_PAIRS: [(OuterBits, OuterBits); 6] = [
    (OuterBits::Fp32, OuterBits::Fp32),
    (OuterBits::Bf16, OuterBits::Bf16),
    (OuterBits::Int8, OuterBits::Int8),
    (OuterBits::Int4, OuterBits::Int4),
    (OuterBits::Int4, OuterBits::Fp32),
    (OuterBits::Fp32, OuterBits::Int4),
];

/// Compressed outer communication (paper section 7; ROADMAP item):
/// the data behind `diloco report --exp comm` — loss delta vs wire
/// bytes over [`COMM_PAIRS`], best-known hypers, no re-tune. The
/// (32, 32) entries are the exact fp32 baselines the deltas are
/// measured against (bit-identical to the uncompressed path).
fn comm_sweep(model: &str) -> Vec<RunConfig> {
    let mut out = Vec::new();
    let c = lr_center(model);
    for m in [2usize, 4] {
        for (up, down) in COMM_PAIRS {
            push(
                &mut out,
                model,
                Algo::DiLoCo { replicas: m },
                16,
                c,
                etas_for(m)[1],
                |cf| {
                    cf.outer_bits = up;
                    cf.outer_bits_down = down;
                },
            );
        }
    }
    out
}

/// The (fragments, τ, up, down) corners the `stream` grid covers,
/// baseline first. With the default H=30 the fragment intervals are
/// H/P ∈ {30, 15}, so every τ obeys the one-in-flight rule τ < H/P:
/// the barrier baseline, streaming without overlap, shallow and deep
/// delayed application, the quantized-overlap corner (4-bit wires both
/// ways — the full Streaming DiLoCo configuration), and a deep window
/// on the unfragmented schedule. Like [`COMM_PAIRS`], this constant is
/// the single source of truth: `report::tables::table_stream` derives
/// its row set from it, so extending the grid extends the report.
pub const STREAM_CORNERS: [(usize, usize, OuterBits, OuterBits); 6] = [
    (1, 0, OuterBits::Fp32, OuterBits::Fp32), // vanilla barrier baseline
    (2, 0, OuterBits::Fp32, OuterBits::Fp32), // streaming fragments, barrier
    (2, 1, OuterBits::Fp32, OuterBits::Fp32), // one-step delayed application
    (2, 7, OuterBits::Fp32, OuterBits::Fp32), // ~half the fragment interval
    (2, 1, OuterBits::Int4, OuterBits::Int4), // overlap + 4-bit wires both ways
    (1, 14, OuterBits::Fp32, OuterBits::Fp32), // deep window, unfragmented
];

/// Overlapped outer sync (Streaming DiLoCo / DiLoCoX; ROADMAP item):
/// the data behind `diloco report --exp stream` — loss vs τ over
/// [`STREAM_CORNERS`], best-known hypers, no re-tune. The (P=1, τ=0)
/// entries are the exact barrier baselines the deltas are measured
/// against (bit-identical to the pre-overlap path).
fn stream_sweep(model: &str) -> Vec<RunConfig> {
    let mut out = Vec::new();
    let c = lr_center(model);
    for m in [2usize, 4] {
        for (p, tau, up, down) in STREAM_CORNERS {
            push(
                &mut out,
                model,
                Algo::DiLoCo { replicas: m },
                16,
                c,
                etas_for(m)[1],
                |cf| {
                    cf.streaming_fragments = p;
                    cf.overlap_tau = tau;
                    cf.outer_bits = up;
                    cf.outer_bits_down = down;
                },
            );
        }
    }
    out
}

/// The fault-plan specs the `churn` grid covers, baseline first: the
/// churn-free anchor (bit-identical to the plain path — the zero row
/// every delta is measured against), a seed-derived random-dropout
/// ladder, an explicit elastic-membership corner (a crash plus a later
/// join), and a straggler-only plan (event journal + walltime model
/// only — the loss trajectory is untouched). Like [`COMM_PAIRS`], this
/// constant is the single source of truth: `report::tables::table_churn`
/// derives its row set from it, so extending the grid extends the
/// report.
pub const CHURN_CORNERS: [&str; 6] = [
    "",
    "rate=0.05",
    "rate=0.1",
    "rate=0.2",
    "crash@2:r1,join@4:r4",
    "straggle@1:r1,straggle@3:r2",
];

/// Elastic membership / crash tolerance (ROADMAP item): the data
/// behind `diloco report --exp churn` — eval loss vs replica dropout
/// rate over [`CHURN_CORNERS`], best-known hypers, no re-tune. The
/// empty-spec entries are the exact churn-free baselines the deltas
/// are measured against.
fn churn_sweep(model: &str) -> Vec<RunConfig> {
    let mut out = Vec::new();
    let c = lr_center(model);
    for m in [2usize, 4] {
        for spec in CHURN_CORNERS {
            push(
                &mut out,
                model,
                Algo::DiLoCo { replicas: m },
                16,
                c,
                etas_for(m)[1],
                |cf| cf.churn = spec.to_string(),
            );
        }
    }
    out
}

/// Composite grids can repeat configurations (e.g. the m8 fast-pass
/// entries also appear in the full m0 grid); keep the first occurrence.
fn dedup_by_run_id(grid: Vec<RunConfig>) -> Vec<RunConfig> {
    let mut seen = std::collections::HashSet::new();
    grid.into_iter()
        .filter(|cfg| seen.insert(crate::sweep::store::run_id(cfg)))
        .collect()
}

/// Grid registry.
pub fn grid_names() -> Vec<&'static str> {
    vec![
        "main-m0", "balanced",
        "main-m1",
        "main-m2",
        "h-sweep",
        "batch",
        "overtrain",
        "comm",
        "stream",
        "churn",
        "all",
        "smoke",
    ]
}

pub fn grid_by_name(name: &str) -> Result<Vec<RunConfig>> {
    Ok(match name {
        "main-m0" => main_grid("m0", 0),
        "main-m1" => main_grid("m1", 1),
        "main-m2" => main_grid("m2", 2),
        "h-sweep" => h_sweep("m0"),
        "batch" => batch_sweep("m0"),
        "overtrain" => overtrain_sweep("m0"),
        "comm" => comm_sweep("m0"),
        "stream" => stream_sweep("m0"),
        "churn" => churn_sweep("m0"),
        // priority order: ladder first (Table 4 / scaling laws), then ablations
        "all" => {
            let mut v = main_grid("m0", 0);
            v.extend(main_grid("m1", 1));
            v.extend(main_grid("m2", 2));
            v.extend(h_sweep("m0"));
            v.extend(batch_sweep("m0"));
            v.extend(overtrain_sweep("m0"));
            v.extend(comm_sweep("m0"));
            v.extend(stream_sweep("m0"));
            v.extend(churn_sweep("m0"));
            dedup_by_run_id(v)
        }
        // wall-clock-constrained order: give every experiment some data
        // early (ladder rungs first, then one pass over each ablation,
        // then the m0 long tail). Resumable against the same store.
        "balanced" => {
            let mut v = main_grid("m1", 1);
            v.extend(main_grid("m2", 2));
            // h-sweep core: enough for fig8/fig9 trends
            let hs = h_sweep("m0");
            v.extend(hs.iter().take(18).cloned());
            v.extend(batch_sweep("m0"));
            v.extend(overtrain_sweep("m0"));
            // compression ladder early: loss-delta-vs-bits needs all
            // four widths of a config before the report says anything
            v.extend(comm_sweep("m0"));
            // overlap corners early for the same reason: loss-vs-τ
            // needs a run per corner before the stream report fills in
            v.extend(stream_sweep("m0"));
            // churn ladder early too: loss-vs-dropout needs the anchor
            // plus at least one faulted run before the report says anything
            v.extend(churn_sweep("m0"));
            // minimal m8 coverage for Table 4's last column
            for b in [16usize, 32] {
                push(&mut v, "m0", Algo::DiLoCo { replicas: 8 }, b, lr_center("m0"), 1.0, |cf| {
                    cf.downstream = true;
                });
            }
            // then everything else
            v.extend(main_grid("m0", 0));
            v.extend(hs.into_iter().skip(18));
            dedup_by_run_id(v)
        }
        "smoke" => {
            let mut cfg = base("m0");
            cfg.token_budget = Some(60_000);
            let mut cfg2 = cfg.clone();
            cfg2.algo = Algo::DiLoCo { replicas: 2 };
            cfg2.sync_every = 10;
            vec![cfg, cfg2]
        }
        other => bail!("unknown grid {other:?}; known: {:?}", grid_names()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::store::run_id;
    use std::collections::HashSet;

    #[test]
    fn all_grids_build_and_have_unique_ids() {
        for name in grid_names() {
            if name == "all" {
                continue;
            }
            let g = grid_by_name(name).unwrap();
            assert!(!g.is_empty(), "{name} empty");
            let ids: HashSet<String> = g.iter().map(run_id).collect();
            assert_eq!(ids.len(), g.len(), "{name} has duplicate run ids");
        }
    }

    #[test]
    fn batches_divide_replicas() {
        for cfg in grid_by_name("all").unwrap() {
            let m = cfg.algo.replicas();
            assert_eq!(cfg.global_batch_seqs % m, 0, "cfg {cfg:?}");
        }
    }

    #[test]
    fn main_m0_covers_all_algorithms() {
        let g = grid_by_name("main-m0").unwrap();
        let algos: HashSet<String> = g.iter().map(|c| c.algo.label()).collect();
        for want in ["dp", "diloco-m1", "diloco-m2", "diloco-m4", "diloco-m8"] {
            assert!(algos.contains(want), "missing {want}");
        }
    }

    #[test]
    fn h_sweep_covers_paper_cadences() {
        let g = grid_by_name("h-sweep").unwrap();
        let hs: HashSet<usize> = g.iter().map(|c| c.sync_every).collect();
        for h in [1, 5, 10, 30, 100, 300] {
            assert!(hs.contains(&h), "missing H={h}");
        }
    }

    #[test]
    fn comm_grid_covers_every_width_pair() {
        let g = grid_by_name("comm").unwrap();
        assert_eq!(g.len(), 12, "2 replica counts x (4 symmetric + 2 asymmetric)");
        let up: HashSet<u32> = g.iter().map(|c| c.outer_bits.bits()).collect();
        let down: HashSet<u32> = g.iter().map(|c| c.outer_bits_down.bits()).collect();
        for b in [32u32, 16, 8, 4] {
            assert!(up.contains(&b), "missing outer_bits={b}");
            assert!(down.contains(&b), "missing outer_bits_down={b}");
        }
        // both asymmetric corners present: each leg narrowed alone
        assert!(g.iter().any(|c| c.outer_bits.bits() == 4 && c.outer_bits_down.bits() == 32));
        assert!(g.iter().any(|c| c.outer_bits.bits() == 32 && c.outer_bits_down.bits() == 4));
        // within a replica count only the widths vary, so the report
        // can attribute the whole loss delta to the codecs
        for w in g.windows(2) {
            if w[0].algo == w[1].algo {
                assert_eq!(w[0].inner_lr, w[1].inner_lr);
                assert_eq!(w[0].outer_lr, w[1].outer_lr);
                assert_eq!(w[0].global_batch_seqs, w[1].global_batch_seqs);
            }
        }
    }

    #[test]
    fn stream_grid_covers_overlap_corners_and_obeys_the_schedule() {
        let g = grid_by_name("stream").unwrap();
        assert_eq!(g.len(), 12, "2 replica counts x 6 corners");
        for cfg in &g {
            let p = cfg.streaming_fragments.max(1);
            assert_eq!(cfg.sync_every % p, 0, "H must divide into fragments: {cfg:?}");
            let interval = cfg.sync_every / p;
            assert!(
                cfg.overlap_tau < interval,
                "one sync in flight: tau {} vs H/P {interval} ({cfg:?})",
                cfg.overlap_tau
            );
        }
        // every corner present per replica count, baseline included
        for m in [2usize, 4] {
            for (p, tau, up, down) in STREAM_CORNERS {
                assert!(
                    g.iter().any(|c| c.algo == (Algo::DiLoCo { replicas: m })
                        && c.streaming_fragments == p
                        && c.overlap_tau == tau
                        && c.outer_bits == up
                        && c.outer_bits_down == down),
                    "missing corner (P={p}, tau={tau}) for M={m}"
                );
            }
        }
        // within a replica count only the schedule/width knobs vary,
        // so the report can attribute the whole loss delta to them
        for w in g.windows(2) {
            if w[0].algo == w[1].algo {
                assert_eq!(w[0].inner_lr, w[1].inner_lr);
                assert_eq!(w[0].outer_lr, w[1].outer_lr);
                assert_eq!(w[0].sync_every, w[1].sync_every);
                assert_eq!(w[0].global_batch_seqs, w[1].global_batch_seqs);
            }
        }
    }

    #[test]
    fn churn_grid_covers_every_corner() {
        let g = grid_by_name("churn").unwrap();
        assert_eq!(g.len(), 12, "2 replica counts x 6 fault plans");
        for m in [2usize, 4] {
            for spec in CHURN_CORNERS {
                assert!(
                    g.iter().any(|c| c.algo == (Algo::DiLoCo { replicas: m })
                        && c.churn == spec),
                    "missing churn corner {spec:?} for M={m}"
                );
            }
        }
        // every spec must parse under the grid's own seeds — a typo in
        // CHURN_CORNERS should fail here, not mid-sweep
        for cfg in &g {
            crate::coordinator::FaultPlan::parse(&cfg.churn, cfg.seed).unwrap();
        }
        // within a replica count only the fault plan varies, so the
        // report can attribute the whole loss delta to churn
        for w in g.windows(2) {
            if w[0].algo == w[1].algo {
                assert_eq!(w[0].inner_lr, w[1].inner_lr);
                assert_eq!(w[0].outer_lr, w[1].outer_lr);
                assert_eq!(w[0].sync_every, w[1].sync_every);
                assert_eq!(w[0].global_batch_seqs, w[1].global_batch_seqs);
            }
        }
    }

    #[test]
    fn lr_grid_uses_sqrt2_steps() {
        let v = lrs(1.0, &[-2, 0, 2]);
        assert!((v[0] - 0.5).abs() < 1e-12);
        assert!((v[2] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn unknown_grid_rejected() {
        assert!(grid_by_name("nope").is_err());
    }
}
