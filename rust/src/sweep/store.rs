//! Resumable sweep result store: JSON-lines, one record per run,
//! keyed by a deterministic run id derived from the full config.
//!
//! # Sharding (scale-out)
//!
//! New records are appended to **per-model shard files** next to the
//! base path — `sweep.jsonl` grows siblings `sweep.m0.jsonl`,
//! `sweep.m1.jsonl`, ... — so a 10^4-run sweep never rewrites or
//! rescans one monolithic file per model-scoped query. On open, the
//! legacy single file (if present) is read first, then every shard,
//! and a small in-memory index (model → sorted run ids) is built so
//! `by_model_algo` touches only the asked-for model's records. Old
//! single-file stores keep reading back unchanged; mixed stores
//! (legacy file + shards) merge, with shard entries winning on id
//! collision (they are strictly newer).

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::{RunConfig, RunMetrics};
use crate::util::json::Json;

/// Deterministic, human-readable id for a run configuration.
/// `outer_bits` / `outer_bits_down` are part of the id because a
/// compressed wire on either leg changes training results, and so are
/// the streaming fragment count (`_p{P}` — the fragment schedule
/// changes which leaves sync when) and the overlap window (`_tau{τ}`
/// — delayed application changes what the outer gradient sees);
/// `workers` and `sync_threads` deliberately are NOT (bit-identical
/// at any thread count — pure wall-clock knobs). For Data-Parallel
/// there is no outer sync
/// at all, so all four knobs are inert and the id pins them to
/// (32, 32, 1, 0) — DP runs differing only in those flags are
/// byte-identical and must collide. A non-empty fault plan changes the
/// trajectory, so it forks the id with a trailing `_ch{spec}` segment
/// (spec sanitized to the filename-safe alphabet); churn-free ids are
/// byte-identical to the pre-churn format, and DP ignores churn
/// entirely (no outer sync to inject faults into).
pub fn run_id(cfg: &RunConfig) -> String {
    let (ob, obd, p, tau) = match cfg.algo {
        crate::coordinator::Algo::DataParallel => (32, 32, 1, 0),
        _ => (
            cfg.outer_bits.bits(),
            cfg.outer_bits_down.bits(),
            cfg.streaming_fragments.max(1),
            cfg.overlap_tau,
        ),
    };
    let churn = if cfg.churn.is_empty()
        || matches!(cfg.algo, crate::coordinator::Algo::DataParallel)
    {
        String::new()
    } else {
        let safe: String = cfg
            .churn
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '.' { c } else { '-' })
            .collect();
        format!("_ch{safe}")
    };
    format!(
        "{}_{}_h{}_b{}_lr{:.5}_eta{:.2}_ot{}_s{}_ob{ob}_obd{obd}_p{p}_tau{tau}{churn}",
        cfg.model,
        cfg.algo.label(),
        cfg.sync_every,
        cfg.global_batch_seqs,
        cfg.inner_lr,
        cfg.outer_lr,
        cfg.overtrain,
        cfg.seed
    )
}

pub struct SweepStore {
    path: PathBuf,
    records: BTreeMap<String, RunMetrics>,
    /// model → run ids, built on load and maintained on insert: the
    /// index that keeps per-model queries from scanning every record.
    by_model: BTreeMap<String, BTreeSet<String>>,
}

/// The shard file a model's records append to: `<stem>.<model>.jsonl`
/// next to the base path (model names are sanitized to the filename-
/// safe alphabet; anything exotic lands in the `other` shard).
fn shard_path(base: &Path, model: &str) -> PathBuf {
    let stem = base
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("sweep");
    let safe: String = model
        .chars()
        .filter(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '_')
        .collect();
    let safe = if safe.is_empty() { "other".to_string() } else { safe };
    base.with_file_name(format!("{stem}.{safe}.jsonl"))
}

impl SweepStore {
    /// Open (creating the parent dir if absent) a store: the legacy
    /// single file at `path` plus every `<stem>.<model>.jsonl` shard
    /// beside it.
    pub fn open(path: &Path) -> Result<SweepStore> {
        let mut store = SweepStore {
            path: path.to_path_buf(),
            records: BTreeMap::new(),
            by_model: BTreeMap::new(),
        };
        if path.is_file() {
            store.read_file(path)?;
        } else if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        // shards, in sorted filename order (deterministic load; shard
        // entries win id collisions against the legacy file)
        let stem = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("sweep")
            .to_string();
        let base_name = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
        if let Some(parent) = path.parent() {
            if parent.as_os_str().is_empty() || parent.is_dir() {
                let dir = if parent.as_os_str().is_empty() {
                    Path::new(".")
                } else {
                    parent
                };
                // only names `shard_path` itself writes qualify:
                // `<stem>.<model>.jsonl` with <model> non-empty and
                // drawn from the sanitized shard alphabet — so a
                // stray `sweep.jsonl.bak` or `sweep.notes 2.jsonl`
                // beside the store is never ingested as a shard
                let is_shard = |n: &str| -> bool {
                    if n == base_name || !n.ends_with(".jsonl") {
                        return false;
                    }
                    n.strip_prefix(&format!("{stem}."))
                        .and_then(|rest| rest.strip_suffix(".jsonl"))
                        .is_some_and(|model| {
                            !model.is_empty()
                                && model.chars().all(|c| {
                                    c.is_ascii_alphanumeric() || c == '-' || c == '_'
                                })
                        })
                };
                let mut shards: Vec<PathBuf> = std::fs::read_dir(dir)?
                    .filter_map(|e| e.ok())
                    .map(|e| e.path())
                    .filter(|p| {
                        p.is_file()
                            && p.file_name()
                                .and_then(|s| s.to_str())
                                .is_some_and(|n| is_shard(n))
                    })
                    .collect();
                shards.sort();
                for shard in shards {
                    store.read_file(&shard)?;
                }
            }
        }
        Ok(store)
    }

    fn read_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line)
                .with_context(|| format!("{}:{}", path.display(), lineno + 1))?;
            let id = j.str_of("id")?;
            let metrics = RunMetrics::from_json(j.req("metrics")?)?;
            self.index(&id, &metrics);
            self.records.insert(id, metrics);
        }
        Ok(())
    }

    fn index(&mut self, id: &str, metrics: &RunMetrics) {
        self.by_model
            .entry(metrics.model.clone())
            .or_default()
            .insert(id.to_string());
    }

    pub fn contains(&self, id: &str) -> bool {
        self.records.contains_key(id)
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Append one record to its model's shard (durable immediately —
    /// O_APPEND semantics).
    pub fn insert(&mut self, id: &str, metrics: &RunMetrics) -> Result<()> {
        let record = Json::obj(vec![
            ("id", Json::str(id)),
            ("metrics", metrics.to_json()),
        ]);
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(shard_path(&self.path, &metrics.model))?;
        writeln!(f, "{}", record.to_string_compact())?;
        self.index(id, metrics);
        self.records.insert(id.to_string(), metrics.clone());
        Ok(())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &RunMetrics)> {
        self.records.iter()
    }

    pub fn records(&self) -> impl Iterator<Item = &RunMetrics> {
        self.records.values()
    }

    /// All records for a given (model, algo label) pair — resolved
    /// through the model index, in run-id order (the same order the
    /// pre-index full scan produced).
    pub fn by_model_algo(&self, model: &str, algo: &str) -> Vec<&RunMetrics> {
        self.by_model.get(model).map_or_else(Vec::new, |ids| {
            ids.iter()
                .filter_map(|id| self.records.get(id))
                .filter(|r| r.model == model && r.algo == algo)
                .collect()
        })
    }

    /// Best (lowest final eval loss) record matching a predicate.
    pub fn best<F: Fn(&RunMetrics) -> bool>(&self, pred: F) -> Option<&RunMetrics> {
        self.records
            .values()
            .filter(|r| pred(r) && r.final_eval_loss.is_finite())
            .min_by(|a, b| a.final_eval_loss.partial_cmp(&b.final_eval_loss).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Algo;

    fn metrics(model: &str, loss: f64) -> RunMetrics {
        RunMetrics {
            model: model.into(),
            algo: "dp".into(),
            replicas: 1,
            sync_every: 0,
            global_batch_tokens: 1024,
            inner_lr: 1e-3,
            outer_lr: 0.0,
            overtrain: 1.0,
            seed: 1,
            param_count: 1000,
            steps: 10,
            tokens: 10240,
            final_eval_loss: loss,
            final_train_loss: loss,
            eval_curve: vec![(10, loss)],
            loss_curve: vec![(1, 6.0), (10, loss)],
            downstream: vec![("cloze-long".into(), 0.5)],
            outer_syncs: 0,
            wall_secs: 1.0,
            fragments: 1,
            overlap_tau: 0,
            outer_bits: 32,
            outer_bits_down: 32,
            wire_up_bytes: 0,
            wire_down_bytes: 0,
            wire_framed_bytes: 0,
            churn: String::new(),
            dropout_rate: 0.0,
            sync_encode_ms: 0.0,
            sync_wire_wait_ms: 0.0,
            sync_reduce_ms: 0.0,
            sync_step_ms: 0.0,
            sync_bcast_ms: 0.0,
        }
    }

    #[test]
    fn run_id_is_deterministic_and_distinct() {
        let a = RunConfig::default();
        let mut b = RunConfig::default();
        assert_eq!(run_id(&a), run_id(&a));
        b.inner_lr *= 2.0;
        assert_ne!(run_id(&a), run_id(&b));
        let mut c = RunConfig::default();
        c.algo = Algo::DiLoCo { replicas: 2 };
        assert_ne!(run_id(&a), run_id(&c));
        // compressed and uncompressed DiLoCo runs must never collide,
        // on either wire direction...
        let mut d = c.clone();
        d.outer_bits = crate::comm::OuterBits::Int4;
        assert_ne!(run_id(&c), run_id(&d));
        assert!(run_id(&c).ends_with("_ob32_obd32_p1_tau0"));
        assert!(run_id(&d).ends_with("_ob4_obd32_p1_tau0"));
        let mut d2 = c.clone();
        d2.outer_bits_down = crate::comm::OuterBits::Int8;
        assert_ne!(run_id(&c), run_id(&d2));
        assert_ne!(run_id(&d), run_id(&d2));
        assert!(run_id(&d2).ends_with("_ob32_obd8_p1_tau0"));
        // fragment count and overlap window change training results,
        // so they fork the id too
        let mut d3 = c.clone();
        d3.streaming_fragments = 2;
        assert_ne!(run_id(&c), run_id(&d3));
        assert!(run_id(&d3).ends_with("_p2_tau0"));
        let mut d4 = c.clone();
        d4.overlap_tau = 3;
        assert_ne!(run_id(&c), run_id(&d4));
        assert_ne!(run_id(&d3), run_id(&d4));
        assert!(run_id(&d4).ends_with("_p1_tau3"));
        // ...while workers and sync_threads stay excluded (both are
        // bit-identical wall-clock knobs)...
        let mut e = RunConfig::default();
        e.workers = 8;
        e.sync_threads = 4;
        assert_eq!(run_id(&a), run_id(&e));
        // ...and DP ids pin ob=obd=32, p=1, tau=0: every outer-sync
        // knob is inert without an outer sync, so differing DP runs
        // are the same run
        let mut f = RunConfig::default();
        f.outer_bits = crate::comm::OuterBits::Int4;
        f.outer_bits_down = crate::comm::OuterBits::Int4;
        f.streaming_fragments = 4;
        f.overlap_tau = 2;
        assert_eq!(run_id(&a), run_id(&f));
        // a fault plan forks the id (sanitized), churn-free keeps the
        // legacy format, and DP ignores churn entirely
        let mut g = c.clone();
        g.churn = "crash@2:r1,rate=0.1".into();
        assert_ne!(run_id(&c), run_id(&g));
        assert!(run_id(&g).ends_with("_chcrash-2-r1-rate-0.1"), "{}", run_id(&g));
        let mut h = RunConfig::default();
        h.churn = "crash@2:r1".into();
        assert_eq!(run_id(&a), run_id(&h));
    }

    #[test]
    fn roundtrip_through_file() {
        let dir = std::env::temp_dir().join(format!("sweep_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("store.jsonl");
        {
            let mut s = SweepStore::open(&path).unwrap();
            s.insert("a", &metrics("m0", 3.5)).unwrap();
            s.insert("b", &metrics("m1", 3.1)).unwrap();
            assert_eq!(s.len(), 2);
        }
        let s = SweepStore::open(&path).unwrap();
        assert_eq!(s.len(), 2);
        assert!(s.contains('a'));
        let best = s.best(|_| true).unwrap();
        assert_eq!(best.model, "m1");
        let rec = &s.by_model_algo("m0", "dp")[0];
        assert_eq!(rec.downstream[0].0, "cloze-long");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shards_by_model_and_still_reads_legacy_single_files() {
        let dir = std::env::temp_dir().join(format!("sweep_shard_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sweep.jsonl");

        // a pre-sharding store: one monolithic file at the base path
        {
            let legacy = Json::obj(vec![
                ("id", Json::str("old0")),
                ("metrics", metrics("m0", 4.0).to_json()),
            ]);
            std::fs::write(&path, format!("{}\n", legacy.to_string_compact())).unwrap();
        }
        {
            let mut s = SweepStore::open(&path).unwrap();
            assert!(s.contains("old0"), "legacy single file must read back");
            // new inserts land in per-model shards, never the base file
            s.insert("a0", &metrics("m0", 3.5)).unwrap();
            s.insert("a1", &metrics("m1", 3.2)).unwrap();
            s.insert("a2", &metrics("m1", 3.1)).unwrap();
        }
        assert!(dir.join("sweep.m0.jsonl").is_file());
        assert!(dir.join("sweep.m1.jsonl").is_file());
        let base_len = std::fs::read_to_string(&path).unwrap().lines().count();
        assert_eq!(base_len, 1, "base file must not grow after sharding");

        // foreign siblings are NOT shards: garbage content here must
        // not break (or leak into) the store
        std::fs::write(dir.join("sweep.notes 2.jsonl"), "not json\n").unwrap();
        std::fs::write(dir.join("sweep.jsonl.bak"), "not json\n").unwrap();

        // reopen: legacy + both shards merge, and the model index
        // routes per-model queries without a full scan
        let s = SweepStore::open(&path).unwrap();
        assert_eq!(s.len(), 4);
        for id in ["old0", "a0", "a1", "a2"] {
            assert!(s.contains(id), "{id}");
        }
        assert_eq!(s.by_model_algo("m0", "dp").len(), 2);
        assert_eq!(s.by_model_algo("m1", "dp").len(), 2);
        assert!(s.by_model_algo("m7", "dp").is_empty());
        assert_eq!(s.best(|_| true).unwrap().final_eval_loss, 3.1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_paths_are_sanitized() {
        let base = Path::new("runs/sweep.jsonl");
        assert_eq!(
            shard_path(base, "m0"),
            Path::new("runs/sweep.m0.jsonl")
        );
        assert_eq!(
            shard_path(base, "../evil/../m0"),
            Path::new("runs/sweep.evilm0.jsonl")
        );
        assert_eq!(shard_path(base, "///"), Path::new("runs/sweep.other.jsonl"));
    }
}
