//! Resumable sweep result store: JSON-lines, one record per run,
//! keyed by a deterministic run id derived from the full config.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::{RunConfig, RunMetrics};
use crate::util::json::Json;

/// Deterministic, human-readable id for a run configuration.
/// `outer_bits` / `outer_bits_down` are part of the id because a
/// compressed wire on either leg changes training results; `workers`
/// deliberately is NOT (bit-identical at any worker count — a pure
/// wall-clock knob). For Data-Parallel there is no outer wire at all,
/// so both knobs are inert and the id pins them to 32 — DP runs
/// differing only in `--outer-bits` / `--outer-bits-down` are
/// byte-identical and must collide.
pub fn run_id(cfg: &RunConfig) -> String {
    let (ob, obd) = match cfg.algo {
        crate::coordinator::Algo::DataParallel => (32, 32),
        _ => (cfg.outer_bits.bits(), cfg.outer_bits_down.bits()),
    };
    format!(
        "{}_{}_h{}_b{}_lr{:.5}_eta{:.2}_ot{}_s{}_ob{ob}_obd{obd}",
        cfg.model,
        cfg.algo.label(),
        cfg.sync_every,
        cfg.global_batch_seqs,
        cfg.inner_lr,
        cfg.outer_lr,
        cfg.overtrain,
        cfg.seed
    )
}

pub struct SweepStore {
    path: PathBuf,
    records: BTreeMap<String, RunMetrics>,
}

impl SweepStore {
    /// Open (creating if absent) a JSON-lines store.
    pub fn open(path: &Path) -> Result<SweepStore> {
        let mut records = BTreeMap::new();
        if path.is_file() {
            let text = std::fs::read_to_string(path)?;
            for (lineno, line) in text.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                let j = Json::parse(line)
                    .with_context(|| format!("{}:{}", path.display(), lineno + 1))?;
                let id = j.str_of("id")?;
                let metrics = RunMetrics::from_json(j.req("metrics")?)?;
                records.insert(id, metrics);
            }
        } else if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(SweepStore {
            path: path.to_path_buf(),
            records,
        })
    }

    pub fn contains(&self, id: &str) -> bool {
        self.records.contains_key(id)
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Append one record (durable immediately — O_APPEND semantics).
    pub fn insert(&mut self, id: &str, metrics: &RunMetrics) -> Result<()> {
        let record = Json::obj(vec![
            ("id", Json::str(id)),
            ("metrics", metrics.to_json()),
        ]);
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        writeln!(f, "{}", record.to_string_compact())?;
        self.records.insert(id.to_string(), metrics.clone());
        Ok(())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &RunMetrics)> {
        self.records.iter()
    }

    pub fn records(&self) -> impl Iterator<Item = &RunMetrics> {
        self.records.values()
    }

    /// All records for a given (model, algo label) pair.
    pub fn by_model_algo(&self, model: &str, algo: &str) -> Vec<&RunMetrics> {
        self.records
            .values()
            .filter(|r| r.model == model && r.algo == algo)
            .collect()
    }

    /// Best (lowest final eval loss) record matching a predicate.
    pub fn best<F: Fn(&RunMetrics) -> bool>(&self, pred: F) -> Option<&RunMetrics> {
        self.records
            .values()
            .filter(|r| pred(r) && r.final_eval_loss.is_finite())
            .min_by(|a, b| a.final_eval_loss.partial_cmp(&b.final_eval_loss).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Algo;

    fn metrics(model: &str, loss: f64) -> RunMetrics {
        RunMetrics {
            model: model.into(),
            algo: "dp".into(),
            replicas: 1,
            sync_every: 0,
            global_batch_tokens: 1024,
            inner_lr: 1e-3,
            outer_lr: 0.0,
            overtrain: 1.0,
            seed: 1,
            param_count: 1000,
            steps: 10,
            tokens: 10240,
            final_eval_loss: loss,
            final_train_loss: loss,
            eval_curve: vec![(10, loss)],
            loss_curve: vec![(1, 6.0), (10, loss)],
            downstream: vec![("cloze-long".into(), 0.5)],
            outer_syncs: 0,
            wall_secs: 1.0,
            outer_bits: 32,
            outer_bits_down: 32,
            wire_up_bytes: 0,
            wire_down_bytes: 0,
        }
    }

    #[test]
    fn run_id_is_deterministic_and_distinct() {
        let a = RunConfig::default();
        let mut b = RunConfig::default();
        assert_eq!(run_id(&a), run_id(&a));
        b.inner_lr *= 2.0;
        assert_ne!(run_id(&a), run_id(&b));
        let mut c = RunConfig::default();
        c.algo = Algo::DiLoCo { replicas: 2 };
        assert_ne!(run_id(&a), run_id(&c));
        // compressed and uncompressed DiLoCo runs must never collide,
        // on either wire direction...
        let mut d = c.clone();
        d.outer_bits = crate::comm::OuterBits::Int4;
        assert_ne!(run_id(&c), run_id(&d));
        assert!(run_id(&c).ends_with("_ob32_obd32"));
        assert!(run_id(&d).ends_with("_ob4_obd32"));
        let mut d2 = c.clone();
        d2.outer_bits_down = crate::comm::OuterBits::Int8;
        assert_ne!(run_id(&c), run_id(&d2));
        assert_ne!(run_id(&d), run_id(&d2));
        assert!(run_id(&d2).ends_with("_ob32_obd8"));
        // ...while workers stays excluded (bit-identical results)...
        let mut e = RunConfig::default();
        e.workers = 8;
        assert_eq!(run_id(&a), run_id(&e));
        // ...and DP ids pin ob=obd=32: both knobs are inert without an
        // outer sync, so differing DP runs are the same run
        let mut f = RunConfig::default();
        f.outer_bits = crate::comm::OuterBits::Int4;
        f.outer_bits_down = crate::comm::OuterBits::Int4;
        assert_eq!(run_id(&a), run_id(&f));
    }

    #[test]
    fn roundtrip_through_file() {
        let dir = std::env::temp_dir().join(format!("sweep_test_{}", std::process::id()));
        let path = dir.join("store.jsonl");
        let _ = std::fs::remove_file(&path);
        {
            let mut s = SweepStore::open(&path).unwrap();
            s.insert("a", &metrics("m0", 3.5)).unwrap();
            s.insert("b", &metrics("m1", 3.1)).unwrap();
            assert_eq!(s.len(), 2);
        }
        let s = SweepStore::open(&path).unwrap();
        assert_eq!(s.len(), 2);
        assert!(s.contains("a"));
        let best = s.best(|_| true).unwrap();
        assert_eq!(best.model, "m1");
        let rec = &s.by_model_algo("m0", "dp")[0];
        assert_eq!(rec.downstream[0].0, "cloze-long");
        std::fs::remove_dir_all(&dir).ok();
    }
}
