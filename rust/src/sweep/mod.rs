//! Hyperparameter sweep harness (paper section 3.1).
//!
//! Grids are named, prioritized lists of [`RunConfig`]s; the runner is
//! resumable — each completed run is appended to a JSON-lines store
//! keyed by a deterministic run id, and already-present ids are
//! skipped. This mirrors the paper's methodology: sweep (inner) LR in
//! powers of sqrt(2), batch size in powers of 2, outer LR in
//! {0.2..1.0}, on every ladder rung, then fit scaling laws to the
//! best-per-(N, M) results.

pub mod grids;
pub mod store;

pub use grids::{grid_by_name, grid_names};
pub use store::{run_id, SweepStore};

use anyhow::Result;

use crate::config::RepoConfig;
use crate::coordinator::{run, RunConfig};
use crate::runtime::{ModelRuntime, Runtime};

/// Execute every run in the grid that is not already in the store.
/// Writes results incrementally; safe to interrupt and re-invoke.
pub fn execute_grid(
    repo: &RepoConfig,
    store: &mut SweepStore,
    grid: &[RunConfig],
    max_runs: Option<usize>,
) -> Result<usize> {
    let rt = Runtime::cpu()?;
    let mut runtimes: std::collections::BTreeMap<String, ModelRuntime> =
        std::collections::BTreeMap::new();
    let mut done = 0usize;
    let todo: Vec<&RunConfig> = grid
        .iter()
        .filter(|cfg| !store.contains(&run_id(cfg)))
        .collect();
    log::info!(
        "grid: {} runs total, {} already done, {} to go",
        grid.len(),
        grid.len() - todo.len(),
        todo.len()
    );
    for cfg in todo {
        if let Some(cap) = max_runs {
            if done >= cap {
                break;
            }
        }
        if !runtimes.contains_key(&cfg.model) {
            runtimes.insert(
                cfg.model.clone(),
                ModelRuntime::load(rt.clone(), &repo.model_dir(&cfg.model))?,
            );
        }
        let mr = &runtimes[&cfg.model];
        let id = run_id(cfg);
        match run(mr, &repo.optimizer, cfg) {
            Ok(metrics) => {
                log::info!(
                    "[sweep] {id}: eval={:.4} ({} steps, {:.1}s)",
                    metrics.final_eval_loss,
                    metrics.steps,
                    metrics.wall_secs
                );
                store.insert(&id, &metrics)?;
                done += 1;
            }
            Err(e) => {
                log::warn!("[sweep] {id} FAILED: {e:#}");
            }
        }
    }
    Ok(done)
}
