//! End-to-end validation driver (DESIGN.md section 9, deliverable b):
//! pretrains a mini-ladder transformer from scratch at full Chinchilla
//! budget with BOTH Data-Parallel and DiLoCo(M=2, H=30), logging the
//! loss curves, final held-out loss, zero-shot accuracy, and the
//! idealized wall-clock each setup would take across the paper's three
//! network archetypes. This is the run recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example e2e_pretrain [model] [budget_tokens]

use diloco::config::RepoConfig;
use diloco::coordinator::{run, Algo, RunConfig};
use diloco::netsim::walltime::{walltime, WalltimeAlgo, WalltimeInput};
use diloco::netsim::ARCHETYPES;
use diloco::runtime::{ModelRuntime, Runtime};

fn main() -> anyhow::Result<()> {
    diloco::util::init_logging();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().cloned().unwrap_or_else(|| "m1".to_string());
    let budget: Option<usize> = args.get(1).map(|s| s.parse()).transpose()?;

    let repo = RepoConfig::load_default()?;
    let rt = Runtime::cpu()?;
    let mr = ModelRuntime::load(rt, &repo.model_dir(&model))?;
    println!(
        "== e2e pretrain: {} ({} params, {} token budget) ==\n",
        model,
        mr.manifest.model.param_count,
        budget.unwrap_or(mr.manifest.model.token_budget)
    );

    let mut results = Vec::new();
    for (algo, eta) in [
        (Algo::DataParallel, 0.0),
        (Algo::DiLoCo { replicas: 2 }, 1.0),
    ] {
        let cfg = RunConfig {
            model: model.clone(),
            algo,
            sync_every: 30,
            global_batch_seqs: 16,
            inner_lr: 6e-3,
            outer_lr: eta,
            token_budget: budget,
            eval_tokens: 16 * 1024,
            eval_every: Some(200),
            log_every: 100,
            downstream: true,
            ..Default::default()
        };
        let m = run(&mr, &repo.optimizer, &cfg)?;
        println!("\n-- {} --", m.algo);
        println!("loss curve (step, train loss): {:?}", m.loss_curve);
        println!("eval curve (step, eval loss):  {:?}", m.eval_curve);
        println!("final eval loss: {:.4}", m.final_eval_loss);
        for (task, acc) in &m.downstream {
            println!("zero-shot {task}: {acc:.3}");
        }
        println!("measured wall: {:.1}s ({} steps)", m.wall_secs, m.steps);
        results.push(m);
    }

    println!("\n== idealized wall-clock (Appendix A model, paper-scale analog) ==");
    println!("{:<10} {:<12} {:>14} {:>14}", "network", "algo", "comm", "total");
    for net in ARCHETYPES {
        for m in &results {
            let algo = if m.algo == "dp" {
                WalltimeAlgo::DataParallel
            } else {
                WalltimeAlgo::DiLoCo {
                    replicas: m.replicas,
                    sync_every: m.sync_every,
                }
            };
            let w = walltime(&WalltimeInput {
                algo,
                params: m.param_count as f64,
                tokens: m.tokens as f64,
                batch_tokens: m.global_batch_tokens as f64,
                cross_dc: net,
                outer_bits: diloco::netsim::walltime::BITS_PER_PARAM,
                outer_bits_down: diloco::netsim::walltime::BITS_PER_PARAM,
            });
            println!(
                "{:<10} {:<12} {:>12.3}s {:>12.3}s",
                net.name,
                m.algo,
                w.comm_s,
                w.total_s()
            );
        }
    }

    let dp = &results[0];
    let dl = &results[1];
    println!("\n== summary ==");
    println!(
        "DP   : eval {:.4}  |  DiLoCo M=2: eval {:.4}  (diff {:+.2}%)",
        dp.final_eval_loss,
        dl.final_eval_loss,
        (dl.final_eval_loss - dp.final_eval_loss) / dp.final_eval_loss * 100.0
    );
    anyhow::ensure!(
        dp.final_eval_loss < 5.9 && dl.final_eval_loss < 5.9,
        "training did not make progress"
    );
    Ok(())
}
