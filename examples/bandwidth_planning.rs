//! Bandwidth planning with the Table 6 simulator: given a model size
//! and step time, print the cross-datacenter bandwidth needed to hit
//! each compute-utilization target for Data-Parallel vs DiLoCo at
//! various sync cadences — the calculation an infra team would run
//! before committing to multi-datacenter training.
//!
//!     cargo run --release --example bandwidth_planning [params_b] [step_s]

use diloco::netsim::utilization::{
    LlmArchetype, SimAlgo, SimModel, CADENCES, CU_TARGETS,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let params_b: f64 = args
        .first()
        .and_then(|s| s.parse().ok())
        .unwrap_or(70.0); // default: a 70B model
    let step_s: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(5.0);

    let arch = LlmArchetype {
        name: "custom",
        params: params_b * 1e9,
        step_time_s: step_s,
    };
    let sim = SimModel::default();

    println!(
        "== bandwidth (Gbit/s) to reach compute utilization — {params_b}B params, {step_s}s/step =="
    );
    print!("{:<18}", "method");
    for cu in CU_TARGETS {
        print!("{:>10}", format!("CU={:.0}%", cu * 100.0));
    }
    println!();
    let mut methods = vec![("Data-Parallel".to_string(), SimAlgo::DataParallel)];
    for h in CADENCES {
        methods.push((format!("DiLoCo, H={h}"), SimAlgo::DiLoCo { sync_every: h }));
    }
    for (label, algo) in methods {
        print!("{label:<18}");
        for cu in CU_TARGETS {
            match sim.required_bandwidth_gbps(&arch, algo, cu) {
                Some(w) => print!("{w:>10}"),
                None => print!("{:>10}", "1000+"),
            }
        }
        println!();
    }
    let dp = sim
        .required_bandwidth_gbps(&arch, SimAlgo::DataParallel, 0.5)
        .unwrap_or(f64::NAN);
    let h300 = sim
        .required_bandwidth_gbps(&arch, SimAlgo::DiLoCo { sync_every: 300 }, 0.5)
        .unwrap_or(f64::NAN);
    println!(
        "\nDiLoCo H=300 needs {:.0}x less cross-DC bandwidth than Data-Parallel at CU=50%.",
        dp / h300
    );
}
