use diloco::config::RepoConfig;
use diloco::coordinator::{run, Algo, RunConfig};
use diloco::runtime::{ModelRuntime, Runtime};
fn main() -> anyhow::Result<()> {
    let repo = RepoConfig::load_default()?;
    let rt = Runtime::cpu()?;
    for model in ["m0", "m2"] {
        let mr = ModelRuntime::load(rt.clone(), &repo.model_dir(model))?;
        for force in [false, true] {
            let cfg = RunConfig {
                model: model.into(), algo: Algo::DataParallel, global_batch_seqs: 8,
                token_budget: Some(65_536), eval_tokens: 1024, log_every: 100_000,
                inner_lr: 1e-2, force_accumulate: force, ..Default::default()
            };
            let t0 = std::time::Instant::now();
            let m = run(&mr, &repo.optimizer, &cfg)?;
            let dt = t0.elapsed().as_secs_f64();
            println!("{model} force_accumulate={force}: {:.2}s for {} steps = {:.1} ms/step (tok/s {:.0}), loss {:.3}",
                dt, m.steps, dt*1e3/m.steps as f64, m.tokens as f64/dt, m.final_eval_loss);
        }
    }
    Ok(())
}
