//! Quickstart: load the AOT artifacts, train the smallest model with
//! DiLoCo (M=2, H=10) for a tiny budget, print the loss trajectory.
//!
//!     make artifacts && cargo run --release --example quickstart

use diloco::config::RepoConfig;
use diloco::coordinator::{run, Algo, RunConfig};
use diloco::runtime::{ModelRuntime, Runtime};

fn main() -> anyhow::Result<()> {
    diloco::util::init_logging();
    let repo = RepoConfig::load_default()?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0"))?;
    println!(
        "model m0: {} params, Chinchilla budget {} tokens",
        mr.manifest.model.param_count, mr.manifest.model.token_budget
    );

    let cfg = RunConfig {
        model: "m0".into(),
        algo: Algo::DiLoCo { replicas: 2 },
        sync_every: 10,
        global_batch_seqs: 16,
        inner_lr: 8.5e-3,
        outer_lr: 0.8,
        token_budget: Some(120_000),
        eval_tokens: 8192,
        eval_every: Some(30),
        log_every: 30,
        downstream: true,
        ..Default::default()
    };
    let m = run(&mr, &repo.optimizer, &cfg)?;

    println!("\n== quickstart result ==");
    println!("algo            : {} (H={})", m.algo, m.sync_every);
    println!("steps           : {} ({} tokens)", m.steps, m.tokens);
    println!("outer syncs     : {}", m.outer_syncs);
    println!("final eval loss : {:.4}", m.final_eval_loss);
    println!("eval curve      : {:?}", m.eval_curve);
    for (task, acc) in &m.downstream {
        println!("zero-shot {task:<12}: {acc:.3}");
    }
    println!("wall time       : {:.1}s", m.wall_secs);
    Ok(())
}
