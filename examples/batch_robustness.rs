//! Batch-size robustness demo (paper Findings 2-3, Figures 3-4):
//! trains m0 at several global batch sizes with Data-Parallel and
//! DiLoCo(M=1), same token budget, and prints loss vs batch. Expect DP
//! to degrade as batch grows while DiLoCo stays flat.
//!
//!     cargo run --release --example batch_robustness

use diloco::config::RepoConfig;
use diloco::coordinator::{run, Algo, RunConfig};
use diloco::runtime::{ModelRuntime, Runtime};

fn main() -> anyhow::Result<()> {
    diloco::util::init_logging();
    let repo = RepoConfig::load_default()?;
    let rt = Runtime::cpu()?;
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0"))?;
    let budget = 250_000usize; // ~half Chinchilla for a fast demo

    println!("{:<12} {:>14} {:>12}", "algo", "batch_tokens", "eval_loss");
    let mut rows: Vec<(String, usize, f64)> = Vec::new();
    for batch_seqs in [8usize, 32, 128] {
        for (algo, eta) in [
            (Algo::DataParallel, 0.0),
            (Algo::DiLoCo { replicas: 1 }, 0.8),
        ] {
            let cfg = RunConfig {
                algo,
                global_batch_seqs: batch_seqs,
                sync_every: 30,
                inner_lr: 8.5e-3,
                outer_lr: eta,
                token_budget: Some(budget),
                eval_tokens: 8192,
                log_every: 1000,
                ..Default::default()
            };
            let m = run(&mr, &repo.optimizer, &cfg)?;
            println!(
                "{:<12} {:>14} {:>12.4}",
                m.algo, m.global_batch_tokens, m.final_eval_loss
            );
            rows.push((m.algo.clone(), m.global_batch_tokens, m.final_eval_loss));
        }
    }

    // The headline shape: DP's degradation from smallest to largest
    // batch should exceed DiLoCo M=1's.
    let span = |algo: &str| {
        let mut v: Vec<(usize, f64)> = rows
            .iter()
            .filter(|r| r.0 == algo)
            .map(|r| (r.1, r.2))
            .collect();
        v.sort_by_key(|r| r.0);
        v.last().unwrap().1 - v.first().unwrap().1
    };
    let dp_span = span("dp");
    let dl_span = span("diloco-m1");
    println!(
        "\nloss increase small->large batch: DP {dp_span:+.4}, DiLoCo M=1 {dl_span:+.4}"
    );
    println!(
        "(paper: DP degrades sharply with batch; DiLoCo tolerates large batches)"
    );
    Ok(())
}
