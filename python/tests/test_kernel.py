"""L1 kernel correctness: Pallas vs pure-jnp oracle.

The CORE build-time correctness signal: hypothesis sweeps shapes, block
sizes, and distributions; every case must match `kernels/ref.py` to
tight tolerance. (Paper section 3: the compute hot-spot must be exact —
scaling-law measurements are loss differences of a fraction of a
percent.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adamw, attention, ref


def _qkv(seed, bh, s, dh, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return tuple(
        jnp.asarray(scale * rng.standard_normal((bh, s, dh)), dtype)
        for _ in range(3)
    )


# ---------------------------------------------------------------------------
# Attention kernel
# ---------------------------------------------------------------------------

class TestAttentionBasic:
    def test_matches_ref_default_blocks(self):
        q, k, v = _qkv(0, 4, 64, 8)
        out = attention.causal_attention(q, k, v)
        np.testing.assert_allclose(out, ref.causal_attention_ref(q, k, v),
                                   rtol=2e-5, atol=2e-5)

    def test_single_row_block(self):
        q, k, v = _qkv(1, 2, 8, 4)
        out = attention.causal_attention(q, k, v, 1, 1)
        np.testing.assert_allclose(out, ref.causal_attention_ref(q, k, v),
                                   rtol=2e-5, atol=2e-5)

    def test_one_block_covers_seq(self):
        q, k, v = _qkv(2, 2, 16, 8)
        out = attention.causal_attention(q, k, v, 16, 16)
        np.testing.assert_allclose(out, ref.causal_attention_ref(q, k, v),
                                   rtol=2e-5, atol=2e-5)

    def test_rectangular_blocks(self):
        q, k, v = _qkv(3, 2, 32, 8)
        out = attention.causal_attention(q, k, v, 16, 8)
        np.testing.assert_allclose(out, ref.causal_attention_ref(q, k, v),
                                   rtol=2e-5, atol=2e-5)

    def test_first_position_is_value(self):
        # Causality: output at t=0 attends only to position 0 => equals v[0].
        q, k, v = _qkv(4, 3, 32, 8)
        out = attention.causal_attention(q, k, v)
        np.testing.assert_allclose(out[:, 0, :], v[:, 0, :], rtol=1e-5, atol=1e-5)

    def test_causality_future_independence(self):
        # Perturbing k/v after position t must not change outputs up to t.
        q, k, v = _qkv(5, 1, 32, 8)
        out1 = attention.causal_attention(q, k, v)
        k2 = k.at[:, 16:, :].add(100.0)
        v2 = v.at[:, 16:, :].set(-7.0)
        out2 = attention.causal_attention(q, k2, v2)
        np.testing.assert_allclose(out1[:, :16], out2[:, :16], rtol=1e-5, atol=1e-5)

    def test_large_logits_stable(self):
        # Online softmax must survive logits ~ +-60 without overflow.
        q, k, v = _qkv(6, 2, 32, 8, scale=20.0)
        out = attention.causal_attention(q, k, v)
        exp = ref.causal_attention_ref(q, k, v)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)

    def test_bad_blocks_rejected(self):
        q, k, v = _qkv(7, 1, 24, 8)
        with pytest.raises(ValueError):
            attention.causal_attention(q, k, v, 16, 16)

    def test_block_q_multiple_of_block_k_required(self):
        q, k, v = _qkv(8, 1, 32, 8)
        with pytest.raises(ValueError):
            attention.causal_attention(q, k, v, 8, 16)

    def test_grad_matches_ref_grad(self):
        q, k, v = _qkv(9, 2, 32, 8)

        def f_pallas(q, k, v):
            return (attention.causal_attention(q, k, v) ** 2).sum()

        def f_ref(q, k, v):
            return (ref.causal_attention_ref(q, k, v) ** 2).sum()

        gp = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


@settings(max_examples=25, deadline=None)
@given(
    bh=st.integers(1, 4),
    s_pow=st.integers(2, 6),            # seq in {4..64}
    dh=st.sampled_from([2, 4, 8, 16]),
    bq_pow=st.integers(0, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_hypothesis_sweep(bh, s_pow, dh, bq_pow, seed):
    """Property: for every legal (shape, blocking), kernel == oracle."""
    s = 2 ** s_pow
    bq = 2 ** min(bq_pow, s_pow)
    bk = bq  # square blocking is always legal when bq | s
    q, k, v = _qkv(seed, bh, s, dh)
    out = attention.causal_attention(q, k, v, bq, bk)
    exp = ref.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Fused AdamW kernel
# ---------------------------------------------------------------------------

def _adamw_case(seed, n, step=3, lr=1e-3, wd=1e-2, gscale=0.7, block=64):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.standard_normal(n), jnp.float32)
    m = jnp.asarray(0.1 * rng.standard_normal(n), jnp.float32)
    v = jnp.asarray(rng.uniform(1e-6, 1.0, n), jnp.float32)
    g = jnp.asarray(rng.standard_normal(n), jnp.float32)
    bc1 = 1.0 / (1.0 - 0.9 ** step)
    bc2 = 1.0 / (1.0 - 0.99 ** step)
    scal = jnp.asarray([lr, wd, bc1, bc2, gscale], jnp.float32)
    got = adamw.fused_adamw(p, m, v, g, scal, block=block)
    want = ref.adamw_ref(p, m, v, g, step=step, lr=lr, wd=wd, grad_scale=gscale)
    return got, want


class TestAdamWKernel:
    def test_matches_ref_exact_block(self):
        got, want = _adamw_case(0, 256, block=64)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)

    def test_matches_ref_ragged_tail(self):
        # n not a multiple of block exercises the pad/strip path.
        got, want = _adamw_case(1, 1000, block=256)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)

    def test_tiny_buffer(self):
        got, want = _adamw_case(2, 3, block=4096)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)

    def test_zero_grad_pure_decay(self):
        n = 64
        p = jnp.ones(n)
        m = jnp.zeros(n)
        v = jnp.zeros(n)
        g = jnp.zeros(n)
        scal = jnp.asarray([0.1, 0.5, 1.0, 1.0, 1.0], jnp.float32)
        p2, m2, v2 = adamw.fused_adamw(p, m, v, g, scal)
        np.testing.assert_allclose(p2, 1.0 - 0.1 * 0.5, rtol=1e-6)
        np.testing.assert_allclose(m2, 0.0)
        np.testing.assert_allclose(v2, 0.0)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 5000),
    step=st.integers(1, 10_000),
    lr=st.floats(1e-5, 1.0),
    wd=st.floats(0.0, 0.1),
    gscale=st.floats(0.01, 1.0),
    block=st.sampled_from([16, 64, 256, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_adamw_hypothesis_sweep(n, step, lr, wd, gscale, block, seed):
    got, want = _adamw_case(seed, n, step=step, lr=lr, wd=wd,
                            gscale=gscale, block=block)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6)
