"""AOT pipeline checks: manifests consistent, HLO text loadable-shaped."""

import json
import os

import pytest

from compile import aot, configs

ART = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "artifacts"))

pytestmark = pytest.mark.skipif(
    not os.path.isdir(os.path.join(ART, "m0")),
    reason="artifacts not built (run `make artifacts`)")


def _manifest(name):
    with open(os.path.join(ART, name, "manifest.json")) as f:
        return json.load(f)


class TestManifest:
    def test_all_models_present(self):
        for m in configs.mini_ladder():
            assert os.path.isfile(os.path.join(ART, m.name, "manifest.json"))

    def test_param_signature_matches_specs(self):
        man = _manifest("m0")
        cfg = configs.model_by_name("m0")
        specs = configs.param_specs(cfg)
        assert len(man["params"]) == len(specs)
        for entry, (name, shape) in zip(man["params"], specs):
            assert entry["name"] == name
            assert tuple(entry["shape"]) == tuple(shape)
            assert entry["dtype"] == "f32"

    def test_artifact_files_exist_and_are_hlo(self):
        man = _manifest("m0")
        expected = {"init", "apply_update", "train_step", "grad_acc",
                    "eval_step", "seq_nll"}
        expected |= {f"grad_step_mb{b}" for b in man["micro_batches"]}
        assert set(man["artifacts"]) == expected
        for art in man["artifacts"].values():
            path = os.path.join(ART, "m0", art["file"])
            assert os.path.isfile(path)
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head

    def test_train_step_io_arity(self):
        man = _manifest("m0")
        n = len(man["params"])
        ts = man["artifacts"]["train_step"]
        assert len(ts["inputs"]) == 3 * n + 4   # p,m,v, tokens, step, lr, wd
        assert len(ts["outputs"]) == 3 * n + 2  # p,m,v, loss, gnorm

    def test_param_count_recorded(self):
        for m in configs.mini_ladder():
            man = _manifest(m.name)
            assert man["model"]["param_count"] == configs.param_count(m)
            assert man["model"]["token_budget"] == configs.token_budget(m)

    def test_source_hash_current(self):
        # Manifests must correspond to the *current* compile sources;
        # otherwise `make artifacts` should have rebuilt them.
        h = aot._source_hash()
        for m in configs.mini_ladder():
            assert _manifest(m.name)["source_hash"] == h, (
                f"{m.name} artifacts stale; run `make artifacts`")


class TestSignatures:
    def test_artifact_defs_cover_micro_batches(self):
        raw = configs.load_raw()
        cfg = configs.model_by_name("m0")
        defs = aot.artifact_defs(cfg, raw["micro_batches"], raw["eval_batch"])
        for mb in raw["micro_batches"]:
            d = defs[f"grad_step_mb{mb}"]
            assert d["inputs"][-1]["shape"] == [mb, cfg.seq_len]

    def test_grad_acc_symmetric_signature(self):
        raw = configs.load_raw()
        cfg = configs.model_by_name("m0")
        defs = aot.artifact_defs(cfg, raw["micro_batches"], raw["eval_batch"])
        d = defs["grad_acc"]
        n = len(configs.param_specs(cfg))
        assert len(d["inputs"]) == 2 * n + 2
        assert len(d["outputs"]) == n
