"""L2 model invariants: shapes, losses, optimizer semantics, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.kernels import ref


CFG = configs.model_by_name("m0")
FLAT = model.init_params(CFG, jnp.uint32(7))
N = len(FLAT)
RNG = np.random.default_rng(123)
TOKS = jnp.asarray(RNG.integers(0, CFG.vocab, size=(4, CFG.seq_len)), jnp.int32)


class TestConfigs:
    def test_ladder_monotone(self):
        ladder = configs.mini_ladder()
        counts = [configs.param_count(m) for m in ladder]
        assert counts == sorted(counts)
        assert all(b == 20 * n for n, b in
                   zip(counts, (configs.token_budget(m) for m in ladder)))

    def test_param_specs_order_stable(self):
        specs = configs.param_specs(CFG)
        assert specs[0][0] == "embed"
        assert specs[-1][0] == "final_ln"
        assert len(specs) == 10 * CFG.layers + 2

    def test_qkv_dims_consistent(self):
        for m in configs.mini_ladder():
            assert m.heads * m.head_dim == m.d_model  # ladder choice
            assert m.d_ff == 4 * m.d_model


class TestForward:
    def test_logit_shape(self):
        params = model.unflatten(CFG, FLAT)
        logits = model.forward(CFG, params, TOKS)
        assert logits.shape == (4, CFG.seq_len, CFG.vocab)

    def test_pallas_ref_parity(self):
        params = model.unflatten(CFG, FLAT)
        l1 = model.forward(CFG, params, TOKS, use_pallas=True)
        l2 = model.forward(CFG, params, TOKS, use_pallas=False)
        np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)

    def test_init_loss_near_log_vocab(self):
        params = model.unflatten(CFG, FLAT)
        loss, (sum_nll, n) = model.loss_fn(CFG, params, TOKS)
        assert abs(float(sum_nll / n) - np.log(CFG.vocab)) < 1.0

    def test_causality_of_loss(self):
        # NLL at position t must not depend on tokens after t+1.
        params = model.unflatten(CFG, FLAT)
        logits1 = model.forward(CFG, params, TOKS)
        toks2 = TOKS.at[:, -1].set((TOKS[:, -1] + 5) % CFG.vocab)
        logits2 = model.forward(CFG, params, toks2)
        np.testing.assert_allclose(logits1[:, :-1], logits2[:, :-1],
                                   rtol=1e-5, atol=1e-5)

    def test_init_deterministic(self):
        a = model.init_params(CFG, jnp.uint32(7))
        b = model.init_params(CFG, jnp.uint32(7))
        c = model.init_params(CFG, jnp.uint32(8))
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
        assert any(not np.array_equal(x, y) for x, y in zip(a, c))


class TestGradStep:
    def test_output_arity(self):
        out = model.grad_step(CFG, FLAT, TOKS)
        assert len(out) == N + 2

    def test_grads_nonzero_everywhere(self):
        out = model.grad_step(CFG, FLAT, TOKS)
        for (name, _), g in zip(configs.param_specs(CFG), out[:N]):
            assert float(jnp.abs(g).max()) > 0, f"dead gradient: {name}"

    def test_grad_matches_ref_path(self):
        out_p = model.grad_step(CFG, FLAT, TOKS, use_pallas=True)
        out_r = model.grad_step(CFG, FLAT, TOKS, use_pallas=False)
        for a, b in zip(out_p, out_r):
            np.testing.assert_allclose(a, b, rtol=5e-3, atol=1e-5)


class TestApplyUpdate:
    def _zeros(self):
        return tuple(jnp.zeros_like(p) for p in FLAT)

    def test_apply_matches_ref_adamw(self):
        grads = model.grad_step(CFG, FLAT, TOKS)[:N]
        m0, v0 = self._zeros(), self._zeros()
        step, lr, wd = jnp.float32(1), jnp.float32(1e-3), jnp.float32(1e-2)
        out = model.apply_update(CFG, FLAT, m0, v0, grads, step, lr, wd)
        gnorm = out[3 * N]
        gcat = jnp.concatenate([g.reshape(-1) for g in grads])
        np.testing.assert_allclose(gnorm, jnp.linalg.norm(gcat), rtol=1e-5)
        gscale = min(1.0, 1.0 / float(gnorm))
        pcat = jnp.concatenate([p.reshape(-1) for p in FLAT])
        p_ref, m_ref, v_ref = ref.adamw_ref(
            pcat, jnp.zeros_like(pcat), jnp.zeros_like(pcat), gcat,
            step=1.0, lr=1e-3, wd=1e-2, grad_scale=gscale)
        got_p = jnp.concatenate([a.reshape(-1) for a in out[:N]])
        np.testing.assert_allclose(got_p, p_ref, rtol=1e-5, atol=1e-7)

    def test_clip_engages_for_huge_grads(self):
        grads = tuple(1e3 * jnp.ones_like(p) for p in FLAT)
        m0, v0 = self._zeros(), self._zeros()
        out = model.apply_update(CFG, FLAT, m0, v0, grads,
                                 jnp.float32(1), jnp.float32(1e-3),
                                 jnp.float32(0.0))
        assert float(out[3 * N]) > 1.0  # gnorm reported pre-clip
        # With clip engaged the first-step update is bounded by ~lr*bc1.
        delta = max(float(jnp.abs(a - b).max()) for a, b in zip(out[:N], FLAT))
        assert delta < 2e-2

    def test_train_step_equals_grad_then_apply(self):
        m0, v0 = self._zeros(), self._zeros()
        s, lr, wd = jnp.float32(1), jnp.float32(1e-3), jnp.float32(1e-2)
        fused = model.train_step(CFG, FLAT, m0, v0, TOKS, s, lr, wd)
        grads = model.grad_step(CFG, FLAT, TOKS)[:N]
        split = model.apply_update(CFG, FLAT, m0, v0, grads, s, lr, wd)
        for a, b in zip(fused[:3 * N], split[:3 * N]):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)


class TestGradAcc:
    def test_weighted_sum(self):
        a = tuple(jnp.full_like(p, 2.0) for p in FLAT)
        b = tuple(jnp.full_like(p, 3.0) for p in FLAT)
        out = model.grad_acc(CFG, a, b, jnp.float32(0.5), jnp.float32(2.0))
        for o in out:
            np.testing.assert_allclose(o, 7.0)

    def test_accumulated_equals_large_batch(self):
        """mean over 2 micro-batches == grad of the concatenated batch."""
        t1, t2 = TOKS[:2], TOKS[2:]
        g_full = model.grad_step(CFG, FLAT, TOKS)[:N]
        g1 = model.grad_step(CFG, FLAT, t1)[:N]
        g2 = model.grad_step(CFG, FLAT, t2)[:N]
        acc = model.grad_acc(CFG, g1, g2, jnp.float32(0.5), jnp.float32(0.5))
        for a, b in zip(acc, g_full):
            np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6)


class TestEvalAndSeqNll:
    def test_eval_step_counts(self):
        sum_nll, n = model.eval_step(CFG, FLAT, TOKS)
        assert float(n) == 4 * (CFG.seq_len - 1)
        assert float(sum_nll) / float(n) == pytest.approx(np.log(CFG.vocab), abs=1.0)

    def test_seq_nll_mask_zero(self):
        toks = TOKS[:1]
        mask = jnp.zeros((1, CFG.seq_len), jnp.float32)
        assert float(model.seq_nll(CFG, FLAT, toks, mask)) == 0.0

    def test_seq_nll_full_mask_equals_eval(self):
        toks = TOKS[:1]
        mask = jnp.ones((1, CFG.seq_len), jnp.float32)
        got = float(model.seq_nll(CFG, FLAT, toks, mask))
        sum_nll, _ = model.eval_step(CFG, FLAT, toks[:1].repeat(1, 0))
        # eval_step on batch of 1 equals full-mask seq_nll
        params = model.unflatten(CFG, FLAT)
        _, (want, _) = model.loss_fn(CFG, params, toks)
        assert got == pytest.approx(float(want), rel=1e-5)

    def test_seq_nll_additive_in_mask(self):
        toks = TOKS[:1]
        m1 = jnp.zeros((1, CFG.seq_len)).at[0, 10:20].set(1.0)
        m2 = jnp.zeros((1, CFG.seq_len)).at[0, 20:30].set(1.0)
        m12 = jnp.zeros((1, CFG.seq_len)).at[0, 10:30].set(1.0)
        a = float(model.seq_nll(CFG, FLAT, toks, m1))
        b = float(model.seq_nll(CFG, FLAT, toks, m2))
        c = float(model.seq_nll(CFG, FLAT, toks, m12))
        assert c == pytest.approx(a + b, rel=1e-4)


class TestTrainingDynamics:
    def test_loss_decreases_under_training(self):
        state = FLAT + tuple(jnp.zeros_like(p) for p in FLAT) * 2
        ts = jax.jit(lambda *a: model.train_step(
            CFG, a[:N], a[N:2 * N], a[2 * N:3 * N], a[3 * N], a[3 * N + 1],
            a[3 * N + 2], a[3 * N + 3]))
        losses = []
        for i in range(25):
            out = ts(*(state + (TOKS, jnp.float32(i + 1), jnp.float32(3e-3),
                                jnp.float32(1e-4))))
            state = out[:3 * N]
            losses.append(float(out[3 * N]))
        assert losses[-1] < losses[0] - 0.5
