"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the L1 kernels are tested against (pytest +
hypothesis sweeps in python/tests/test_kernel.py), and double as the
`use_pallas=False` execution path of the L2 model.
"""

from __future__ import annotations

import jax.numpy as jnp


def causal_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Naive causal softmax attention.

    Args:
      q, k, v: [batch_heads, seq, head_dim]
    Returns:
      [batch_heads, seq, head_dim]
    """
    _, s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    logits = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, :, :], logits, jnp.asarray(-1e30, q.dtype))
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", probs, v)


def adamw_ref(p, m, v, g, *, step, lr, wd, grad_scale,
              beta1=0.9, beta2=0.99, eps=1e-8):
    """Reference AdamW with decoupled weight decay and gradient scaling.

    `step` is 1-based. `grad_scale` is the global-norm clip multiplier
    (min(1, clip/||g||)), applied to the gradient before the moment
    updates — identical semantics to clipping the batch gradient
    (paper section 3: inner gradients clipped to global l2 norm 1).
    Returns (p', m', v').
    """
    g = g * grad_scale
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    bc1 = 1.0 / (1.0 - beta1 ** step)
    bc2 = 1.0 / (1.0 - beta2 ** step)
    update = (m_new * bc1) / (jnp.sqrt(v_new * bc2) + eps)
    p_new = p - lr * (update + wd * p)
    return p_new, m_new, v_new
