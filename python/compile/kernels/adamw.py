"""L1 Pallas kernel: fused AdamW update over a flat parameter buffer.

The L2 `apply_update` concatenates every parameter leaf into a single
flat f32 vector (the "fused buffer" layout real fused optimizers use),
and this kernel sweeps it in `block` chunks: p/m/v/g tiles stream
through VMEM, the five scalars (lr, wd, bias corrections, clip scale)
ride along as a broadcast block. interpret=True on this CPU image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adamw_kernel(s_ref, p_ref, m_ref, v_ref, g_ref,
                  po_ref, mo_ref, vo_ref, *, beta1: float, beta2: float,
                  eps: float):
    lr, wd, bc1, bc2, gscale = (s_ref[i] for i in range(5))
    g = g_ref[...] * gscale
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    update = (m * bc1) / (jnp.sqrt(v * bc2) + eps)
    po_ref[...] = p_ref[...] - lr * (update + wd * p_ref[...])
    mo_ref[...] = m
    vo_ref[...] = v


def fused_adamw(p: jnp.ndarray, m: jnp.ndarray, v: jnp.ndarray,
                g: jnp.ndarray, scalars: jnp.ndarray, *,
                beta1: float = 0.9, beta2: float = 0.99, eps: float = 1e-8,
                block: int = 4096, interpret: bool = True):
    """Fused AdamW over flat [n] buffers.

    Args:
      p, m, v, g: flat f32 [n] (n need not be a multiple of `block`;
        the tail is padded internally and stripped on return).
      scalars: f32 [5] = (lr, wd, bias_corr1, bias_corr2, grad_scale).
    Returns:
      (p', m', v') flat f32 [n].
    """
    n = p.shape[0]
    block = min(block, max(n, 1))
    padded = (n + block - 1) // block * block
    pad = padded - n
    if pad:
        # v is padded with ones so sqrt stays well-conditioned in the tail.
        p, m, g = (jnp.pad(a, (0, pad)) for a in (p, m, g))
        v = jnp.pad(v, (0, pad), constant_values=1.0)
    kernel = functools.partial(_adamw_kernel, beta1=beta1, beta2=beta2, eps=eps)
    grid = (padded // block,)
    shape = jax.ShapeDtypeStruct((padded,), jnp.float32)
    tile = pl.BlockSpec((block,), lambda i: (i,))
    p2, m2, v2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((5,), lambda i: (0,)), tile, tile, tile, tile],
        out_specs=(tile, tile, tile),
        out_shape=(shape, shape, shape),
        interpret=interpret,
    )(scalars, p, m, v, g)
    if pad:
        p2, m2, v2 = p2[:n], m2[:n], v2[:n]
    return p2, m2, v2
