"""L1 Pallas kernel: blockwise causal flash attention.

The paper's compute hot-spot is the transformer forward/backward; its
dominant non-matmul cost is attention. This kernel implements the
flash-attention schedule in Pallas: the grid tiles (batch*heads, query
blocks); each grid cell holds a `block_q` slab of queries in VMEM and
streams KV in `block_k` chunks with an online-softmax accumulator.

TPU adaptation notes (DESIGN.md section 8): the BlockSpec below is the
HBM<->VMEM schedule a real TPU run would use (q slab resident, KV
streamed, fp32 accumulators, q@k^T contraction MXU-shaped). On this
CPU-only image the kernel MUST run with interpret=True — real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
                 seq_len: int, scale: float):
    """One grid cell: queries [block_q, dh] vs all causal KV blocks."""
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # [bq, dh]
    bq, dh = q.shape

    row_ids = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)

    def body(j, carry):
        m_prev, l_prev, acc_prev = carry
        # The leading (block-local) batch index must be a dslice, not a
        # bare int: jax 0.4.37's interpret-mode discharge rejects int
        # indices in pl.load (`'int' object has no attribute 'shape'`).
        kv_idx = (pl.dslice(0, 1), pl.dslice(j * block_k, block_k), slice(None))
        k_blk = pl.load(k_ref, kv_idx)[0]
        v_blk = pl.load(v_ref, kv_idx)[0]
        s = jax.lax.dot_general(
            q, k_blk.astype(jnp.float32),
            dimension_numbers=(((1,), (1,)), ((), ())),
        )  # [bq, bk]
        col_ids = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
        s = jnp.where(row_ids >= col_ids, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc_new = acc_prev * alpha[:, None] + p @ v_blk.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, dh), jnp.float32)
    # Causality: KV blocks strictly after this query slab contribute nothing.
    n_blocks = (qi + 1) * block_q // block_k
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def _causal_attention_fwd_only(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                               *, block_q: int = 16, block_k: int = 16,
                               interpret: bool = True) -> jnp.ndarray:
    """Causal flash attention over [batch_heads, seq, head_dim] tensors.

    block_q must divide seq and be a multiple of block_k (the causal
    frontier is computed in whole KV blocks).
    """
    bh, s, dh = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k or block_q % block_k:
        raise ValueError(
            f"seq={s} must be divisible by block_q={block_q} and block_k={block_k}, "
            f"and block_q must be a multiple of block_k")
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_len=s,
        scale=1.0 / (dh ** 0.5))
    grid = (bh, s // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Differentiable wrapper. pallas_call has no JVP rule, so the training
# path uses a custom VJP: the Pallas kernel computes the forward; the
# backward is the (mathematically identical) reference attention's VJP.
# This is the standard pattern for flash-style kernels whose backward
# kernel is authored separately — here the reference VJP doubles as that
# backward until a dedicated Pallas bwd kernel lands.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def causal_attention(q, k, v, block_q: int = 16, block_k: int = 16,
                     interpret: bool = True):
    """Differentiable causal flash attention ([batch_heads, seq, head_dim])."""
    return _causal_attention_fwd_only(
        q, k, v, block_q=block_q, block_k=block_k, interpret=interpret)


def _fwd(q, k, v, block_q, block_k, interpret):
    out = _causal_attention_fwd_only(
        q, k, v, block_q=block_q, block_k=block_k, interpret=interpret)
    return out, (q, k, v)


def _bwd(block_q, block_k, interpret, res, g):
    from . import ref as kernels_ref  # local import to avoid cycle

    q, k, v = res
    _, vjp = jax.vjp(kernels_ref.causal_attention_ref, q, k, v)
    return vjp(g)


causal_attention.defvjp(_fwd, _bwd)
