"""L2: decoder-only transformer (paper section 3) + AdamW inner optimizer.

Chinchilla-style architecture with the paper's stability choices:
QK-LayerNorm (Wortsman et al. 2023), z-loss regularization (Chowdhery et
al. 2023), tied input/output embeddings, RoPE positions, pre-LN blocks.
Attention runs through the L1 Pallas kernel (kernels/attention.py); the
AdamW parameter update runs through the L1 fused kernel
(kernels/adamw.py). Everything here exists only at build time — aot.py
lowers these functions to HLO text once, and the Rust coordinator
executes the artifacts.

All public entry points take/return *flat tuples* of arrays in the
canonical `configs.param_specs` order — that order is the wire format
shared with Rust via each artifact's manifest.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import configs
from .kernels import adamw as adamw_kernel
from .kernels import attention as attention_kernel
from .kernels import ref as kernels_ref

Params = Dict[str, jnp.ndarray]

ADAM_BETA1 = 0.9
ADAM_BETA2 = 0.99
ADAM_EPS = 1e-8
GRAD_CLIP = 1.0


# ---------------------------------------------------------------------------
# Parameter plumbing: flat tuple <-> dict in canonical spec order.
# ---------------------------------------------------------------------------

def unflatten(cfg: configs.ModelConfig, flat: Sequence[jnp.ndarray]) -> Params:
    specs = configs.param_specs(cfg)
    assert len(flat) == len(specs), (len(flat), len(specs))
    out = {}
    for (name, shape), arr in zip(specs, flat):
        assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
        out[name] = arr
    return out


def flatten(cfg: configs.ModelConfig, params: Params) -> Tuple[jnp.ndarray, ...]:
    return tuple(params[name] for name, _ in configs.param_specs(cfg))


def init_params(cfg: configs.ModelConfig, seed: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    """Deterministic init from a u32 seed; lowered as the `init` artifact.

    Truncated-normal fan-in scaling for projection matrices, N(0,1) for
    the (tied) embedding table, ones for all norm scales.
    """
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    specs = configs.param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    out: List[jnp.ndarray] = []
    for k, (name, shape) in zip(keys, specs):
        base = name.rsplit(".", 1)[-1]
        if base in ("ln1", "ln2", "final_ln", "q_norm", "k_norm"):
            out.append(jnp.ones(shape, jnp.float32))
        elif base == "embed":
            # 1/sqrt(d) rows; the input path rescales by sqrt(d) so both
            # the input activations and the tied-head logits start at O(1)
            # (init CE ~ ln(vocab), the NanoDO recipe).
            std = shape[1] ** -0.5
            out.append(std * jax.random.normal(k, shape, jnp.float32))
        else:
            # Clipped (not truncated) normal: jax's truncated_normal
            # lowers through `erf`, an opcode the image's XLA 0.5.1 HLO
            # parser rejects; clipping at 3 sigma is an equivalent
            # stability guard for init purposes.
            fan_in = shape[0]
            std = fan_in ** -0.5
            sample = jnp.clip(jax.random.normal(k, shape, jnp.float32), -3.0, 3.0)
            out.append(std * sample)
    return tuple(out)


# ---------------------------------------------------------------------------
# Forward pass.
# ---------------------------------------------------------------------------

def _layer_norm(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    x = x - x.mean(axis=-1, keepdims=True)
    rms = jnp.sqrt((x * x).mean(axis=-1, keepdims=True) + 1e-6)
    return (x / rms) * scale


def _rope(x: jnp.ndarray) -> jnp.ndarray:
    """Rotary position embedding over [batch, seq, heads, head_dim]."""
    _, s, _, dh = x.shape
    half = dh // 2
    freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def forward(cfg: configs.ModelConfig, params: Params, tokens: jnp.ndarray,
            *, use_pallas: bool = True) -> jnp.ndarray:
    """Logits [batch, seq, vocab] for int32 tokens [batch, seq]."""
    b, s = tokens.shape
    h, dh = cfg.heads, cfg.head_dim
    x = params["embed"][tokens] * (cfg.d_model ** 0.5)  # [b, s, d]
    for i in range(cfg.layers):
        p = f"layer{i}."
        y = _layer_norm(x, params[p + "ln1"])
        q = (y @ params[p + "wq"]).reshape(b, s, h, dh)
        k = (y @ params[p + "wk"]).reshape(b, s, h, dh)
        v = (y @ params[p + "wv"]).reshape(b, s, h, dh)
        # QK-LayerNorm (over head_dim) then RoPE, per the paper's recipe.
        q = _rope(_layer_norm(q, params[p + "q_norm"]))
        k = _rope(_layer_norm(k, params[p + "k_norm"]))
        # Fold batch*heads for the kernel: [b*h, s, dh].
        qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        if use_pallas:
            of = attention_kernel.causal_attention(qf, kf, vf)
        else:
            of = kernels_ref.causal_attention_ref(qf, kf, vf)
        o = of.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, h * dh)
        x = x + o @ params[p + "wo"]
        y = _layer_norm(x, params[p + "ln2"])
        x = x + jax.nn.gelu(y @ params[p + "w1"]) @ params[p + "w2"]
    x = _layer_norm(x, params["final_ln"])
    return x @ params["embed"].T  # tied output head


def loss_from_logits(cfg: configs.ModelConfig, logits: jnp.ndarray,
                     tokens: jnp.ndarray):
    """Mean next-token CE + z-loss; also returns (sum_nll, num_targets)."""
    targets = tokens[:, 1:]
    logits = logits[:, :-1, :].astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    target_logit = jnp.take_along_axis(
        logits, targets[..., None], axis=-1).squeeze(-1)
    nll = lse - target_logit
    sum_nll = nll.sum()
    n = nll.size
    ce = sum_nll / n
    z_loss = cfg.z_loss * (lse * lse).mean()
    return ce + z_loss, (sum_nll, jnp.asarray(n, jnp.float32))


def loss_fn(cfg: configs.ModelConfig, params: Params, tokens: jnp.ndarray,
            *, use_pallas: bool = True):
    logits = forward(cfg, params, tokens, use_pallas=use_pallas)
    return loss_from_logits(cfg, logits, tokens)


# ---------------------------------------------------------------------------
# AOT entry points (flat signatures).
# ---------------------------------------------------------------------------

def grad_step(cfg: configs.ModelConfig, flat_params: Sequence[jnp.ndarray],
              tokens: jnp.ndarray, *, use_pallas: bool = True):
    """Micro-batch fwd+bwd: returns (grads..., mean_loss, sum_nll)."""
    params = unflatten(cfg, flat_params)

    def f(p):
        return loss_fn(cfg, p, tokens, use_pallas=use_pallas)

    (loss, (sum_nll, _)), grads = jax.value_and_grad(f, has_aux=True)(params)
    return tuple(flatten(cfg, grads)) + (loss, sum_nll)


def _global_norm(flat: Sequence[jnp.ndarray]) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in flat))


def _leaf_sizes(cfg: configs.ModelConfig) -> List[int]:
    out = []
    for _, shape in configs.param_specs(cfg):
        n = 1
        for d in shape:
            n *= d
        out.append(n)
    return out


def apply_update(cfg: configs.ModelConfig,
                 flat_params: Sequence[jnp.ndarray],
                 flat_m: Sequence[jnp.ndarray],
                 flat_v: Sequence[jnp.ndarray],
                 flat_grads: Sequence[jnp.ndarray],
                 step: jnp.ndarray, lr: jnp.ndarray, wd: jnp.ndarray,
                 *, use_pallas: bool = True):
    """Clip-to-GRAD_CLIP + fused AdamW. Returns (params'..., m'..., v'..., gnorm).

    `step` is the 1-based f32 step counter (for bias correction); `lr`
    and `wd` are per-step scalars computed by the Rust schedule — keeping
    them as runtime inputs means one artifact serves every schedule,
    batch size, and weight-decay policy (the paper's lambda = 1/T depends
    on the run's total step count T).
    """
    gnorm = _global_norm(flat_grads)
    gscale = jnp.minimum(1.0, GRAD_CLIP / jnp.maximum(gnorm, 1e-12))
    bc1 = 1.0 / (1.0 - ADAM_BETA1 ** step)
    bc2 = 1.0 / (1.0 - ADAM_BETA2 ** step)
    sizes = _leaf_sizes(cfg)
    p_flat = jnp.concatenate([a.reshape(-1) for a in flat_params])
    m_flat = jnp.concatenate([a.reshape(-1) for a in flat_m])
    v_flat = jnp.concatenate([a.reshape(-1) for a in flat_v])
    g_flat = jnp.concatenate([a.reshape(-1) for a in flat_grads])
    scalars = jnp.stack([lr, wd, bc1, bc2, gscale]).astype(jnp.float32)
    if use_pallas:
        p2, m2, v2 = adamw_kernel.fused_adamw(
            p_flat, m_flat, v_flat, g_flat, scalars,
            beta1=ADAM_BETA1, beta2=ADAM_BETA2, eps=ADAM_EPS)
    else:
        p2, m2, v2 = kernels_ref.adamw_ref(
            p_flat, m_flat, v_flat, g_flat * scalars[4], step=step, lr=lr,
            wd=wd, grad_scale=1.0, beta1=ADAM_BETA1, beta2=ADAM_BETA2,
            eps=ADAM_EPS)
    out_p, out_m, out_v = [], [], []
    off = 0
    for (_, shape), n in zip(configs.param_specs(cfg), sizes):
        out_p.append(p2[off:off + n].reshape(shape))
        out_m.append(m2[off:off + n].reshape(shape))
        out_v.append(v2[off:off + n].reshape(shape))
        off += n
    return tuple(out_p) + tuple(out_m) + tuple(out_v) + (gnorm,)


def train_step(cfg: configs.ModelConfig,
               flat_params: Sequence[jnp.ndarray],
               flat_m: Sequence[jnp.ndarray],
               flat_v: Sequence[jnp.ndarray],
               tokens: jnp.ndarray,
               step: jnp.ndarray, lr: jnp.ndarray, wd: jnp.ndarray,
               *, use_pallas: bool = True):
    """Fused grad+apply fast path (one PJRT dispatch per inner step).

    Returns (params'..., m'..., v'..., loss, gnorm).
    """
    n = len(flat_params)
    out = grad_step(cfg, flat_params, tokens, use_pallas=use_pallas)
    grads, loss = out[:n], out[n]
    upd = apply_update(cfg, flat_params, flat_m, flat_v, grads, step, lr, wd,
                       use_pallas=use_pallas)
    return upd[:3 * n] + (loss, upd[3 * n])


def grad_acc(cfg: configs.ModelConfig, a: Sequence[jnp.ndarray],
             b: Sequence[jnp.ndarray], wa: jnp.ndarray, wb: jnp.ndarray):
    """Weighted device-side accumulation: a*wa + b*wb per leaf."""
    del cfg
    return tuple(x * wa + y * wb for x, y in zip(a, b))


def eval_step(cfg: configs.ModelConfig, flat_params: Sequence[jnp.ndarray],
              tokens: jnp.ndarray, *, use_pallas: bool = True):
    """Exact held-out metrics: (sum_nll, num_targets) — no z-loss."""
    params = unflatten(cfg, flat_params)
    _, (sum_nll, n) = loss_fn(cfg, params, tokens, use_pallas=use_pallas)
    return sum_nll, n


def seq_nll(cfg: configs.ModelConfig, flat_params: Sequence[jnp.ndarray],
            tokens: jnp.ndarray, mask: jnp.ndarray, *, use_pallas: bool = True):
    """Masked sequence NLL for zero-shot multiple-choice scoring.

    tokens: [1, seq]; mask: f32 [1, seq], 1.0 on *target* positions
    (mask[t]=1 means "score the prediction of tokens[t] from t-1").
    Returns the summed NLL over masked positions.
    """
    params = unflatten(cfg, flat_params)
    logits = forward(cfg, params, tokens, use_pallas=use_pallas).astype(jnp.float32)
    targets = tokens[:, 1:]
    logits = logits[:, :-1, :]
    lse = jax.nn.logsumexp(logits, axis=-1)
    target_logit = jnp.take_along_axis(
        logits, targets[..., None], axis=-1).squeeze(-1)
    nll = lse - target_logit
    return (nll * mask[:, 1:]).sum()
