"""AOT pipeline: lower L2 entry points to HLO *text* artifacts.

Python runs ONCE, here. For every model in the mini ladder this emits
`artifacts/<model>/{init,grad_step_mb*,apply_update,train_step,grad_acc,
eval_step,seq_nll}.hlo.txt` plus a `manifest.json` that pins the flat
parameter order, every artifact's input/output signature, and a content
hash for incremental rebuilds. The Rust runtime consumes only these
files; Python is never on the request path.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape: Tuple[int, ...], dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(entries: Sequence[Tuple[str, Tuple[int, ...], str]]) -> List[dict]:
    return [{"name": n, "shape": list(s), "dtype": d} for n, s, d in entries]


def _param_sig(cfg, prefix="") -> List[Tuple[str, Tuple[int, ...], str]]:
    return [(prefix + n, s, "f32") for n, s in configs.param_specs(cfg)]


def artifact_defs(cfg: configs.ModelConfig, micro_batches: Sequence[int],
                  eval_batch: int) -> Dict[str, dict]:
    """Name -> {fn, arg specs, input/output signature} for one model."""
    p_specs = [_spec(s) for _, s in configs.param_specs(cfg)]
    n = len(p_specs)
    s64 = cfg.seq_len
    f32 = lambda: _spec((), jnp.float32)
    defs: Dict[str, dict] = {}

    defs["init"] = dict(
        fn=lambda seed: model.init_params(cfg, seed),
        args=[_spec((), jnp.uint32)],
        inputs=_sig([("seed", (), "u32")]),
        outputs=_sig(_param_sig(cfg)),
    )

    for mb in micro_batches:
        defs[f"grad_step_mb{mb}"] = dict(
            fn=lambda *a, _mb=mb: model.grad_step(cfg, a[:n], a[n]),
            args=p_specs + [_spec((mb, s64), jnp.int32)],
            inputs=_sig(_param_sig(cfg) + [("tokens", (mb, s64), "i32")]),
            outputs=_sig(_param_sig(cfg, "grad.") +
                         [("loss", (), "f32"), ("sum_nll", (), "f32")]),
        )

    defs["apply_update"] = dict(
        fn=lambda *a: model.apply_update(
            cfg, a[:n], a[n:2 * n], a[2 * n:3 * n], a[3 * n:4 * n],
            a[4 * n], a[4 * n + 1], a[4 * n + 2]),
        args=p_specs * 4 + [f32(), f32(), f32()],
        inputs=_sig(_param_sig(cfg) + _param_sig(cfg, "m.") +
                    _param_sig(cfg, "v.") + _param_sig(cfg, "grad.") +
                    [("step", (), "f32"), ("lr", (), "f32"), ("wd", (), "f32")]),
        outputs=_sig(_param_sig(cfg) + _param_sig(cfg, "m.") +
                     _param_sig(cfg, "v.") + [("gnorm", (), "f32")]),
    )

    mb0 = micro_batches[-1]
    defs["train_step"] = dict(
        fn=lambda *a: model.train_step(
            cfg, a[:n], a[n:2 * n], a[2 * n:3 * n], a[3 * n],
            a[3 * n + 1], a[3 * n + 2], a[3 * n + 3]),
        args=p_specs * 3 + [_spec((mb0, s64), jnp.int32), f32(), f32(), f32()],
        inputs=_sig(_param_sig(cfg) + _param_sig(cfg, "m.") +
                    _param_sig(cfg, "v.") +
                    [("tokens", (mb0, s64), "i32"), ("step", (), "f32"),
                     ("lr", (), "f32"), ("wd", (), "f32")]),
        outputs=_sig(_param_sig(cfg) + _param_sig(cfg, "m.") +
                     _param_sig(cfg, "v.") +
                     [("loss", (), "f32"), ("gnorm", (), "f32")]),
    )

    defs["grad_acc"] = dict(
        fn=lambda *a: model.grad_acc(cfg, a[:n], a[n:2 * n], a[2 * n], a[2 * n + 1]),
        args=p_specs * 2 + [f32(), f32()],
        inputs=_sig(_param_sig(cfg, "a.") + _param_sig(cfg, "b.") +
                    [("wa", (), "f32"), ("wb", (), "f32")]),
        outputs=_sig(_param_sig(cfg, "grad.")),
    )

    defs["eval_step"] = dict(
        fn=lambda *a: model.eval_step(cfg, a[:n], a[n]),
        args=p_specs + [_spec((eval_batch, s64), jnp.int32)],
        inputs=_sig(_param_sig(cfg) + [("tokens", (eval_batch, s64), "i32")]),
        outputs=_sig([("sum_nll", (), "f32"), ("count", (), "f32")]),
    )

    defs["seq_nll"] = dict(
        fn=lambda *a: model.seq_nll(cfg, a[:n], a[n], a[n + 1]),
        args=p_specs + [_spec((1, s64), jnp.int32), _spec((1, s64), jnp.float32)],
        inputs=_sig(_param_sig(cfg) +
                    [("tokens", (1, s64), "i32"), ("mask", (1, s64), "f32")]),
        outputs=_sig([("sum_nll", (), "f32")]),
    )
    return defs


def _source_hash() -> str:
    """Hash of all compile-path sources + config — incremental rebuild key."""
    h = hashlib.sha256()
    here = os.path.dirname(os.path.abspath(__file__))
    files = [configs.CONFIG_PATH]
    for root, _, names in os.walk(here):
        for name in sorted(names):
            if name.endswith(".py"):
                files.append(os.path.join(root, name))
    for path in sorted(files):
        with open(path, "rb") as f:
            h.update(path.encode())
            h.update(f.read())
    return h.hexdigest()[:16]


def build_model(cfg: configs.ModelConfig, out_dir: str, raw: dict,
                src_hash: str, force: bool = False) -> bool:
    """Lower all artifacts for one model. Returns True if work was done."""
    model_dir = os.path.join(out_dir, cfg.name)
    manifest_path = os.path.join(model_dir, "manifest.json")
    if not force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                if json.load(f).get("source_hash") == src_hash:
                    print(f"[aot] {cfg.name}: up to date")
                    return False
        except (json.JSONDecodeError, OSError):
            pass
    os.makedirs(model_dir, exist_ok=True)
    defs = artifact_defs(cfg, raw["micro_batches"], raw["eval_batch"])
    manifest = {
        "model": {
            "name": cfg.name, "layers": cfg.layers, "d_model": cfg.d_model,
            "heads": cfg.heads, "head_dim": cfg.head_dim, "d_ff": cfg.d_ff,
            "vocab": cfg.vocab, "seq_len": cfg.seq_len,
            "param_count": configs.param_count(cfg),
            "token_budget": configs.token_budget(cfg),
        },
        "params": _sig(_param_sig(cfg)),
        "micro_batches": list(raw["micro_batches"]),
        "eval_batch": raw["eval_batch"],
        "optimizer": raw["optimizer"],
        "artifacts": {},
        "source_hash": src_hash,
    }
    for name, d in defs.items():
        t0 = time.time()
        lowered = jax.jit(d["fn"]).lower(*d["args"])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(model_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname, "inputs": d["inputs"], "outputs": d["outputs"],
        }
        print(f"[aot] {cfg.name}/{name}: {len(text)} chars "
              f"({time.time() - t0:.1f}s)")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    return True


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.normpath(
        os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")))
    ap.add_argument("--models", default="",
                    help="comma-separated subset (default: whole mini ladder)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    raw = configs.load_raw()
    ladder = configs.mini_ladder()
    if args.models:
        want = set(args.models.split(","))
        ladder = [m for m in ladder if m.name in want]
        missing = want - {m.name for m in ladder}
        if missing:
            sys.exit(f"unknown models: {sorted(missing)}")
    src_hash = _source_hash()
    t0 = time.time()
    did = 0
    for cfg in ladder:
        did += build_model(cfg, args.out, raw, src_hash, force=args.force)
    print(f"[aot] done: {did}/{len(ladder)} models rebuilt "
          f"in {time.time() - t0:.1f}s -> {args.out}")


if __name__ == "__main__":
    main()
