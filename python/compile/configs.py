"""Shared model-ladder configuration (python side).

`configs/models.json` is the single source of truth for the model ladder;
this module turns it into typed configs and the *canonical parameter
flatten order* that both the JAX lowering (aot.py) and the Rust runtime
(via each artifact's manifest.json) agree on.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Tuple

_HERE = os.path.dirname(os.path.abspath(__file__))
CONFIG_PATH = os.path.normpath(os.path.join(_HERE, "..", "..", "configs", "models.json"))


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of one rung of the ladder (decoder-only transformer)."""

    name: str
    layers: int
    d_model: int
    heads: int
    head_dim: int
    d_ff: int
    vocab: int
    seq_len: int
    z_loss: float

    @property
    def qkv_dim(self) -> int:
        return self.heads * self.head_dim


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    beta1: float
    beta2: float
    eps: float
    grad_clip: float


def load_raw(path: str = CONFIG_PATH) -> dict:
    with open(path) as f:
        return json.load(f)


def mini_ladder(path: str = CONFIG_PATH) -> List[ModelConfig]:
    raw = load_raw(path)
    out = []
    for m in raw["mini_ladder"]:
        out.append(
            ModelConfig(
                name=m["name"],
                layers=m["layers"],
                d_model=m["d_model"],
                heads=m["heads"],
                head_dim=raw["head_dim"],
                d_ff=m["d_model"] * raw["mlp_ratio"],
                vocab=raw["tokenizer"]["vocab_size"],
                seq_len=raw["seq_len"],
                z_loss=raw["z_loss"],
            )
        )
    return out


def model_by_name(name: str, path: str = CONFIG_PATH) -> ModelConfig:
    for m in mini_ladder(path):
        if m.name == name:
            return m
    raise KeyError(f"unknown model {name!r}")


def optimizer_config(path: str = CONFIG_PATH) -> OptimizerConfig:
    inner = load_raw(path)["optimizer"]["inner"]
    return OptimizerConfig(
        beta1=inner["beta1"], beta2=inner["beta2"], eps=inner["eps"],
        grad_clip=inner["grad_clip"],
    )


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical, ordered list of (name, shape) parameter leaves.

    This order *is* the wire format between python and rust: every
    artifact's flattened parameter inputs/outputs follow it exactly.
    """
    d, f, dh = cfg.d_model, cfg.d_ff, cfg.head_dim
    specs: List[Tuple[str, Tuple[int, ...]]] = [("embed", (cfg.vocab, d))]
    for i in range(cfg.layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1", (d,)),
            (p + "wq", (d, cfg.heads * dh)),
            (p + "wk", (d, cfg.heads * dh)),
            (p + "wv", (d, cfg.heads * dh)),
            (p + "wo", (cfg.heads * dh, d)),
            (p + "q_norm", (dh,)),
            (p + "k_norm", (dh,)),
            (p + "ln2", (d,)),
            (p + "w1", (d, f)),
            (p + "w2", (f, d)),
        ]
    specs.append(("final_ln", (d,)))
    return specs


def param_count(cfg: ModelConfig) -> int:
    """Total trainable parameters (embedding included; tied output head)."""
    import math

    return sum(math.prod(s) for _, s in param_specs(cfg))


def token_budget(cfg: ModelConfig, multiplier: float | None = None,
                 path: str = CONFIG_PATH) -> int:
    """Chinchilla-style budget D = 20 * N (paper section 3.1)."""
    if multiplier is None:
        multiplier = load_raw(path)["token_multiplier"]
    return int(param_count(cfg) * multiplier)
